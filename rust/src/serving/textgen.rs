//! Text-generation engine — the paper's Fig. 1 (right) demo: "given a
//! starting sentence, it can automatically generate new sentences by
//! word", at the real-time (~45 ms/token) target.
//!
//! Decoding is *prefill-then-step* (`crate::decode`): the prompt runs
//! once through the causal prefill graph, whose per-layer K/V
//! projections land directly in a pool-paged [`crate::decode::KvCache`];
//! each generated token then runs the single-position step graph over
//! the borrowed cache feeds, so per-token cost is independent of how
//! many tokens were generated before. The full-resequence path
//! ([`DecodeMode::FullResequence`]) re-runs the whole static-shape
//! sequence per token — it is the bitwise reference for the cached path
//! (`tests/decode_differential.rs`) and the paper-shaped baseline the
//! `bench_textgen` table compares against.
//!
//! Both engines share ONE decode-loop skeleton ([`decode_loop`]): prompt
//! encoding + truncation, the generation loop, seeded sampling, and
//! `per_token_ms` accounting are written once, so the PJRT and native
//! backends cannot drift.
//!
//! * [`GenEngine`] — the AOT `gen_b1` artifact on PJRT (fixed `[1, seq]`
//!   signature, full re-forward per token; no cache feeds exist in the
//!   artifact).
//! * [`NativeGenEngine`] — compiler-IR causal LM on the wave-parallel
//!   arena executor; optionally pruned/INT8 via `compress`, optionally
//!   warmup-calibrated to static activation scales
//!   ([`NativeGenEngine::calibrate_warmup`]).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use super::batcher::BatchModel;
use super::metrics::EngineMetrics;
use super::trace::{armed, Phase, RequestTrace};
use crate::compiler::exec::{ExecBackend, ExecError};
use crate::compress::{prune_model, CompressionConfig, CompressionReport};
use crate::decode::{DecodeError, DecodeMode, DecodeSession, Decoder};
use crate::model::{build_causal_lm, BertConfig};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Executable, Runtime};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub text: String,
    pub tokens_generated: usize,
    /// Per-token forward latencies (for the demo's tokens/s display).
    /// Entry 0 covers the prefill + first token; later entries are
    /// steady-state steps.
    pub per_token_ms: Vec<f64>,
    /// The serving trace id this response was recorded under (`None`
    /// when no tracer was attached) — lets load harnesses join caller
    /// latency to the retained span tree.
    pub request_id: Option<u64>,
}

impl GenResponse {
    /// Mean forward latency per generated token; `None` when no token
    /// was generated (e.g. a prompt already at the sequence cap, or
    /// `max_new_tokens == 0`). Report sites must handle `None` — a plain
    /// `sum / len` here used to print `NaN tok/s`.
    pub fn mean_ms_per_token(&self) -> Option<f64> {
        if self.per_token_ms.is_empty() {
            None
        } else {
            Some(self.per_token_ms.iter().sum::<f64>() / self.per_token_ms.len() as f64)
        }
    }
}

/// Encode a prompt for decoding: ids capped to the embedding rows, empty
/// prompts fall back to `[CLS]`, and prompts at/over the sequence length
/// truncate deterministically to `seq - 1` (one free slot keeps
/// generation possible). Shared by every backend.
pub(crate) fn encode_prompt(tok: &Tokenizer, prompt: &str, vocab: usize, seq: usize) -> Vec<i32> {
    let mut ids: Vec<i32> = tok
        .encode(prompt)
        .iter()
        .map(|&t| (t as i32).min(vocab as i32 - 1))
        .collect();
    if ids.is_empty() {
        ids.push(crate::tokenizer::CLS as i32);
    }
    if ids.len() >= seq {
        ids.truncate(seq - 1);
    }
    ids
}

/// The ONE decode-loop skeleton shared by the PJRT and native engines
/// (and by both native decode modes): prompt encoding, loop control and
/// the `seq` cap, per-token timing, seeded sampling, and final text
/// decoding. `forward(ids, logits)` must fill `logits` with the
/// next-token logits row for the prefix `ids`; the loop reuses one
/// buffer, so a backend that writes in place allocates nothing per token.
///
/// Timing boundary: `per_token_ms` covers the WHOLE forward closure —
/// including host logits readback on the PJRT backend (the historical
/// PJRT loop stopped the clock before readback, so its numbers were
/// slightly lower for the identical model). One uniform boundary across
/// backends is what makes the `bench_textgen` rows comparable.
pub(crate) fn decode_loop<E>(
    tokenizer: &Tokenizer,
    seq: usize,
    vocab: usize,
    req: &GenRequest,
    mut forward: impl FnMut(&[i32], &mut Vec<f32>) -> Result<(), E>,
) -> Result<GenResponse, E> {
    let mut rng = Rng::new(req.seed);
    let mut ids = encode_prompt(tokenizer, &req.prompt, vocab, seq);
    let mut per_token_ms = Vec::new();
    let mut generated = 0usize;
    let mut logits: Vec<f32> = Vec::new();
    while generated < req.max_new_tokens && ids.len() < seq {
        let t0 = std::time::Instant::now();
        forward(&ids, &mut logits)?;
        per_token_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let next = rng.sample_logits(&logits, req.temperature) as i32;
        ids.push(next.min(vocab as i32 - 1));
        generated += 1;
    }
    let text = tokenizer.decode(&ids.iter().map(|&i| i as u32).collect::<Vec<_>>());
    Ok(GenResponse { text, tokens_generated: generated, per_token_ms, request_id: None })
}

pub struct GenEngine {
    pub tokenizer: Arc<Tokenizer>,
    exe: Arc<Executable>,
    /// Device-resident parameters, uploaded once (§Perf).
    params: Vec<xla::PjRtBuffer>,
    pub seq: usize,
    pub vocab: usize,
}

impl GenEngine {
    pub fn new(rt: &mut Runtime, tokenizer: Arc<Tokenizer>) -> Result<Self> {
        let exe = rt.load("gen_b1")?;
        let params = rt.load_params_buffers("gen")?;
        let seq = rt.manifest.models["gen"].cfg("seq");
        let vocab = rt.manifest.models["gen"].cfg("vocab");
        Ok(GenEngine { tokenizer, exe, params, seq, vocab })
    }

    /// Replace parameters (e.g. after LM fine-tuning via crate::train):
    /// uploads the trained literals to the device once.
    pub fn set_params(&mut self, rt: &Runtime, params: &[xla::Literal]) -> anyhow::Result<()> {
        self.params =
            params.iter().map(|l| rt.upload(l)).collect::<anyhow::Result<Vec<_>>>()?;
        Ok(())
    }

    /// Autoregressive decode over the AOT causal-LM executable: the fixed
    /// `[1, seq]` artifact has no cache feeds, so every token re-runs the
    /// full sequence (the shared loop keeps everything else identical to
    /// the native engine).
    pub fn generate(&self, req: &GenRequest) -> Result<GenResponse> {
        decode_loop(&self.tokenizer, self.seq, self.vocab, req, |ids, out| {
            let used = ids.len();
            let mut padded = ids.to_vec();
            padded.resize(self.seq, 0);
            let mut mask = vec![0.0f32; self.seq];
            for m in mask.iter_mut().take(used) {
                *m = 1.0;
            }
            let outs = self.exe.run_device(
                &self.params,
                &[lit_i32(&padded, &[1, self.seq])?, lit_f32(&mask, &[1, self.seq])?],
            )?;
            let logits = to_vec_f32(&outs[0])?; // [1, seq, vocab]
            out.clear();
            out.extend_from_slice(&logits[(used - 1) * self.vocab..used * self.vocab]);
            Ok(())
        })
    }
}

// ---- native backend -----------------------------------------------------

/// PJRT-free text-generation engine on the wave-parallel arena executor,
/// with the same request/response types as [`GenEngine`]. Serves the
/// *position-true causal* LM (`model::build_causal_lm_with`) in either
/// decode mode — [`DecodeMode::KvCache`] (default: prefill once, then
/// O(seq·hidden) per token) or [`DecodeMode::FullResequence`] (the
/// bitwise-identical reference) — optionally structurally pruned and/or
/// INT8-quantized via the `compress` subsystem.
pub struct NativeGenEngine {
    pub tokenizer: Arc<Tokenizer>,
    decoder: Decoder,
    weights: HashMap<String, Vec<f32>>,
    cfg: BertConfig,
    /// What compression this engine serves.
    pub compression: CompressionConfig,
    pub report: CompressionReport,
    /// Worker threads per forward in the wave executor.
    pub threads: usize,
    /// Executor worker source, held for the engine's lifetime: a
    /// persistent [`crate::compiler::exec::WorkerPool`] by default, so
    /// steady-state decode spawns no threads and reuses warm kernel
    /// scratch per token. Swap in [`ExecBackend::scoped`] via
    /// [`NativeGenEngine::with_backend`] for the spawn-per-wave bitwise
    /// reference.
    backend: ExecBackend,
    /// Default decode mode for [`NativeGenEngine::generate`].
    pub mode: DecodeMode,
    /// Lock-free serving metrics: `ttft` is prefill + first token,
    /// `token_latency` the steady-state per-step cost. Clone the `Arc`
    /// before moving the engine into a `Batcher` to keep observing it.
    pub metrics: Arc<EngineMetrics>,
    /// When true, KV-cache sessions time their decode phases
    /// (`decode::DecodePhases`: prefill vs step compute vs cache writes)
    /// and fold the breakdown into `metrics.decode_phases` per request.
    /// Off by default — the per-token path then reads no extra clock.
    pub phase_timing: bool,
}

impl NativeGenEngine {
    pub fn new(tokenizer: Arc<Tokenizer>, cfg: BertConfig, threads: usize) -> Self {
        Self::with_compression(tokenizer, cfg, threads, CompressionConfig::none())
    }

    /// Dense weight draw, structured pruning (graph dims + weights
    /// together), then prefill/step compilation and (optionally) the
    /// int8 tables for both graphs.
    pub fn with_compression(
        tokenizer: Arc<Tokenizer>,
        cfg: BertConfig,
        threads: usize,
        compression: CompressionConfig,
    ) -> Self {
        let dense = build_causal_lm(&cfg);
        let mut weights = super::init_weights(&dense, 0x6E6E_57A7);
        // Shared prune + report accounting (`compress::prune_model`); the
        // decode engine then compiles BOTH graphs at the pruned dims.
        let (dims, mut report) = prune_model(&cfg, &mut weights, &compression);
        let mut decoder = Decoder::new(cfg, dims, compression);
        if compression.int8 {
            decoder.quantize(&weights);
            report.quantized_params = decoder
                .prefill
                .quant_sites
                .iter()
                .filter_map(|s| weights.get(&s.name))
                .map(|v| v.len())
                .sum();
        }
        NativeGenEngine {
            tokenizer,
            decoder,
            weights,
            cfg,
            compression,
            report,
            threads: threads.max(1),
            backend: ExecBackend::pool(threads.max(1)),
            mode: DecodeMode::KvCache,
            metrics: Arc::new(EngineMetrics::default()),
            phase_timing: false,
        }
    }

    /// Small default configuration for demos and benches.
    pub fn demo(tokenizer: Arc<Tokenizer>, threads: usize) -> Self {
        let cfg = BertConfig { vocab: 2048, seq: 64, layers: 2, hidden: 128, heads: 4, inter: 512 };
        Self::new(tokenizer, cfg, threads)
    }

    /// Replace the executor worker source (e.g.
    /// [`ExecBackend::scoped`] to serve on the historical
    /// spawn-per-wave path as a bitwise reference).
    pub fn with_backend(mut self, backend: ExecBackend) -> Self {
        self.threads = backend.threads().max(1);
        self.backend = backend;
        self
    }

    /// The engine's executor worker source (pool stats live here).
    pub fn backend(&self) -> &ExecBackend {
        &self.backend
    }

    /// The compiled decode artifacts (tests, benches, pricing).
    pub fn decoder(&self) -> &Decoder {
        &self.decoder
    }

    /// The engine's named weight map (post-pruning shapes).
    pub fn weights(&self) -> &HashMap<String, Vec<f32>> {
        &self.weights
    }

    /// Warmup calibration (ROADMAP follow-up): run the given prompts
    /// through the fp32 reference, record every quantized matmul's input
    /// range, and switch the int8 path from per-row dynamic to
    /// calibrated-static activation scales — installed in BOTH decode
    /// graphs by weight name, so cached and full-resequence decode stay
    /// bitwise identical after calibration. No-op (returns 0) on fp32
    /// engines.
    pub fn calibrate_warmup(&mut self, prompts: &[&str]) -> Result<usize, ExecError> {
        let (seq, vocab) = (self.cfg.seq, self.cfg.vocab);
        let feeds: Vec<Vec<f32>> = prompts
            .iter()
            .map(|&p| {
                let ids = encode_prompt(&self.tokenizer, p, vocab, seq);
                let mut padded: Vec<f32> = ids.iter().map(|&i| i as f32).collect();
                padded.resize(seq, 0.0);
                padded
            })
            .collect();
        self.decoder.calibrate(&self.weights, &feeds)
    }

    /// Enable continuous-batching decode: compile the batched step-graph
    /// ladder up to `max_slots` concurrent sessions (see
    /// [`Decoder::enable_batched_steps`]) and, on INT8 engines, build its
    /// quantization tables — inheriting any already-calibrated static
    /// activation scales, so enable/calibrate order does not matter.
    pub fn enable_batched(&mut self, max_slots: usize) {
        self.decoder.enable_batched_steps(max_slots);
        if self.compression.int8 {
            self.decoder.quantize_ladder(&self.weights);
        }
    }

    /// Cap the shared KV page pool (total pages across all in-flight
    /// sessions; `None` = unbounded). Under the cap, admitting a session
    /// past capacity fails *that session* with
    /// [`DecodeError::PagePoolExhausted`].
    pub fn cap_kv_pages(&mut self, max_pages: Option<usize>) {
        self.decoder.cap_pages(max_pages);
    }

    /// Generate text. Malformed requests and decode misuse surface as
    /// typed [`DecodeError`]s (executor failures wrapped inside) — the
    /// serving layer rejects the request instead of panicking.
    pub fn generate(&self, req: &GenRequest) -> Result<GenResponse, DecodeError> {
        self.generate_with_mode(req, self.mode)
    }

    /// Decode with an explicit mode (the differential tests pin
    /// `KvCache` == `FullResequence` bitwise at matched seeds). Records
    /// TTFT and per-token step latency into [`NativeGenEngine::metrics`].
    pub fn generate_with_mode(
        &self,
        req: &GenRequest,
        mode: DecodeMode,
    ) -> Result<GenResponse, DecodeError> {
        self.generate_traced(req, mode, &mut None)
    }

    /// Like [`NativeGenEngine::generate`], but records request-scoped
    /// spans (prefill, per-token steps as occupancy-1 waves) into
    /// `trace` when it is detail-sampled, and stamps the response with
    /// the trace id. Tracing is span bookkeeping around unchanged decode
    /// calls — traced output is bitwise equal to untraced.
    pub fn generate_with_trace(
        &self,
        req: &GenRequest,
        trace: &mut Option<RequestTrace>,
    ) -> Result<GenResponse, DecodeError> {
        self.generate_traced(req, self.mode, trace)
    }

    fn generate_traced(
        &self,
        req: &GenRequest,
        mode: DecodeMode,
        trace: &mut Option<RequestTrace>,
    ) -> Result<GenResponse, DecodeError> {
        self.metrics.requests.inc();
        let mut res = self.generate_uninstrumented(req, mode, trace);
        match &mut res {
            Ok(resp) => {
                resp.request_id = trace.as_ref().map(|t| t.id);
                if let Some(&first) = resp.per_token_ms.first() {
                    self.metrics.ttft.record_value((first * 1e3) as u64);
                }
                for &ms in resp.per_token_ms.iter().skip(1) {
                    self.metrics.token_latency.record_value((ms * 1e3) as u64);
                }
            }
            Err(_) => self.metrics.failures.inc(),
        }
        res
    }

    fn generate_uninstrumented(
        &self,
        req: &GenRequest,
        mode: DecodeMode,
        trace: &mut Option<RequestTrace>,
    ) -> Result<GenResponse, DecodeError> {
        let (seq, vocab) = (self.cfg.seq, self.cfg.vocab);
        match mode {
            DecodeMode::FullResequence => {
                // Loop-invariant request map + logits scratch: only the
                // padded ids mutate per token.
                let mut request: HashMap<String, Vec<f32>> = HashMap::new();
                request.insert("input_ids".to_string(), vec![0.0; seq]);
                let mut full = vec![0.0f32; seq * vocab];
                decode_loop(&self.tokenizer, seq, vocab, req, |ids, out| {
                    let used = ids.len();
                    let padded = request.get_mut("input_ids").expect("inserted above");
                    for (i, x) in padded.iter_mut().enumerate() {
                        *x = ids.get(i).copied().unwrap_or(0) as f32;
                    }
                    self.decoder.reseq_forward(
                        &request,
                        &self.weights,
                        &self.backend,
                        &mut full,
                    )?;
                    out.clear();
                    out.extend_from_slice(&full[(used - 1) * vocab..used * vocab]);
                    Ok(())
                })
            }
            DecodeMode::KvCache => {
                let mut session: Option<DecodeSession> = None;
                let resp = decode_loop(&self.tokenizer, seq, vocab, req, |ids, out| {
                    let t0 = armed(trace).then(std::time::Instant::now);
                    if session.is_none() {
                        // First forward: prefill the prompt into the cache.
                        let mut s = self.decoder.begin(&self.weights, &self.backend);
                        if self.phase_timing {
                            s.enable_phase_timing();
                        }
                        session = Some(s);
                        let row = session.as_mut().expect("just set").prefill(ids)?;
                        out.clear();
                        out.extend_from_slice(row);
                        if let (Some(t0), Some(t)) = (t0, trace.as_mut()) {
                            t.span_from(Phase::Prefill, t0);
                        }
                        return Ok(());
                    }
                    let s = session.as_mut().expect("checked above");
                    debug_assert_eq!(s.position() + 1, ids.len());
                    let row = s.step(*ids.last().expect("prompt is never empty"))?;
                    out.clear();
                    out.extend_from_slice(row);
                    if let (Some(t0), Some(t)) = (t0, trace.as_mut()) {
                        let dur = t0.elapsed().as_nanos() as u64;
                        t.span_at(Phase::StepWave, t0, dur, 1, 1);
                    }
                    Ok(())
                });
                if let Some(s) = session {
                    if self.phase_timing {
                        self.metrics.decode_phases.record(&s.phases());
                    }
                    s.finish(); // return the cache pages for the next request
                }
                resp
            }
        }
    }
}

/// Adapter: the native generation engine is a batch model for the
/// dynamic batcher. Generation requests are long-running relative to QA,
/// so batches are singles (`max_batch` 1) — the bounded queue still
/// provides admission control and fair FIFO service under load; decode
/// errors map to an error-text response (mirroring the QA adapter) so
/// one bad request cannot take the worker down.
impl BatchModel<GenRequest, GenResponse> for NativeGenEngine {
    fn max_batch(&self) -> usize {
        1
    }

    fn run_batch(&self, items: &[GenRequest]) -> Vec<GenResponse> {
        items
            .iter()
            .map(|req| match self.generate(req) {
                Ok(r) => r,
                Err(e) => GenResponse {
                    text: format!("<error: {e}>"),
                    tokens_generated: 0,
                    per_token_ms: Vec::new(),
                    request_id: None,
                },
            })
            .collect()
    }

    fn run_batch_traced(
        &self,
        items: &[GenRequest],
        traces: &mut [Option<RequestTrace>],
    ) -> Vec<GenResponse> {
        items
            .iter()
            .zip(traces.iter_mut())
            .map(|(req, trace)| match self.generate_with_trace(req, trace) {
                Ok(r) => r,
                Err(e) => GenResponse {
                    text: format!("<error: {e}>"),
                    tokens_generated: 0,
                    per_token_ms: Vec::new(),
                    request_id: trace.as_ref().map(|t| t.id),
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::{Tokenizer, Vocab};

    fn tiny_engine(threads: usize) -> NativeGenEngine {
        let corpus = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word .";
        let tok = Arc::new(Tokenizer::new(Vocab::build(corpus, 256)));
        let cfg = BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
        NativeGenEngine::new(tok, cfg, threads)
    }

    #[test]
    fn native_generation_is_deterministic_across_threads() {
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 11,
        };
        let r1 = tiny_engine(1).generate(&req).unwrap();
        let r2 = tiny_engine(4).generate(&req).unwrap();
        assert_eq!(r1.tokens_generated, 4);
        assert_eq!(r1.text, r2.text, "wave executor must not change sampling");
        assert_eq!(r1.per_token_ms.len(), 4);
    }

    #[test]
    fn cached_and_resequence_modes_agree() {
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 5,
            temperature: 0.8,
            seed: 23,
        };
        let eng = tiny_engine(2);
        let kv = eng.generate_with_mode(&req, DecodeMode::KvCache).unwrap();
        let full = eng.generate_with_mode(&req, DecodeMode::FullResequence).unwrap();
        assert_eq!(kv.text, full.text, "KV cache must not change sampling");
        assert_eq!(kv.tokens_generated, full.tokens_generated);
        // Back-to-back cached requests recycle the cache pages.
        let _ = eng.generate(&req).unwrap();
        assert_eq!(eng.decoder().pooled_caches(), 1);
    }

    #[test]
    fn compressed_generation_is_deterministic_and_smaller() {
        let corpus = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word .";
        let mk = |threads: usize| {
            let tok = Arc::new(Tokenizer::new(Vocab::build(corpus, 256)));
            let cfg =
                BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
            NativeGenEngine::with_compression(
                tok,
                cfg,
                threads,
                CompressionConfig::pruned_int8(0.5, 0.5),
            )
        };
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 3,
            temperature: 0.0,
            seed: 11,
        };
        let e1 = mk(1);
        assert!(e1.report.params_after < e1.report.params_before);
        assert!(e1.report.size_ratio() > 1.5, "{}", e1.report.size_ratio());
        let r1 = e1.generate(&req).unwrap();
        let r4 = mk(4).generate(&req).unwrap();
        assert_eq!(r1.text, r4.text, "compressed decode must not depend on threads");
        assert_eq!(r1.tokens_generated, 3);
    }

    #[test]
    fn native_generation_respects_sequence_cap() {
        let req = GenRequest {
            prompt: "the quick brown fox jumps over the lazy dog".into(),
            max_new_tokens: 64,
            temperature: 0.5,
            seed: 3,
        };
        let r = tiny_engine(2).generate(&req).unwrap();
        assert!(r.tokens_generated < 64, "seq cap must stop generation");
    }

    #[test]
    fn mean_ms_per_token_guards_empty() {
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 0,
            temperature: 0.0,
            seed: 1,
        };
        let r = tiny_engine(1).generate(&req).unwrap();
        assert_eq!(r.tokens_generated, 0);
        assert_eq!(r.mean_ms_per_token(), None, "no tokens -> no mean, not NaN");

        let some = GenResponse {
            text: String::new(),
            tokens_generated: 2,
            per_token_ms: vec![2.0, 4.0],
            request_id: None,
        };
        assert_eq!(some.mean_ms_per_token(), Some(3.0));
    }

    #[test]
    fn generation_records_engine_metrics() {
        let eng = tiny_engine(1);
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 3,
            temperature: 0.0,
            seed: 5,
        };
        let r = eng.generate(&req).unwrap();
        assert_eq!(r.tokens_generated, 3);
        assert_eq!(eng.metrics.requests.get(), 1);
        assert_eq!(eng.metrics.ttft.len(), 1, "prefill+first token is one TTFT sample");
        assert_eq!(eng.metrics.token_latency.len(), 2, "two steady-state steps");
        assert_eq!(eng.metrics.failures.get(), 0);

        // Zero-token requests record a request but no latency samples.
        let none = GenRequest { max_new_tokens: 0, ..req };
        eng.generate(&none).unwrap();
        assert_eq!(eng.metrics.requests.get(), 2);
        assert_eq!(eng.metrics.ttft.len(), 1);
    }

    #[test]
    fn phase_timing_records_decode_breakdown() {
        let mut eng = tiny_engine(1);
        eng.phase_timing = true;
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 3,
            temperature: 0.0,
            seed: 7,
        };
        eng.generate(&req).unwrap();
        let ph = &eng.metrics.decode_phases;
        assert_eq!(ph.steps.get(), 2, "3 tokens = prefill+first, then 2 steps");
        assert!(ph.prefill_ns.get() > 0, "prefill forward was timed");
        assert!(ph.step_compute_ns.get() > 0, "step forwards were timed");
        assert!(eng.metrics.summary().contains("phases["), "{}", eng.metrics.summary());

        // Off by default: a fresh engine records nothing per token.
        let quiet = tiny_engine(1);
        quiet.generate(&req).unwrap();
        assert_eq!(quiet.metrics.decode_phases.steps.get(), 0);
        assert!(quiet.metrics.decode_phases.summary().is_none());
    }

    #[test]
    fn gen_engine_serves_through_the_batcher() {
        use crate::serving::batcher::{Batcher, BatcherOptions};
        let eng = tiny_engine(1);
        let metrics = Arc::clone(&eng.metrics);
        let b = Batcher::new(eng, BatcherOptions::default());
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 2,
            temperature: 0.0,
            seed: 9,
        };
        let resp = b.call(req).expect("no batcher fault");
        assert_eq!(resp.tokens_generated, 2);
        assert_eq!(metrics.requests.get(), 1, "engine metrics visible from outside");
        b.shutdown();
    }
}
