//! Text-generation engine — the paper's Fig. 1 (right) demo: "given a
//! starting sentence, it can automatically generate new sentences by
//! word."
//!
//! Autoregressive decode over the causal-LM executable (gen_b1): at each
//! step the full (static-shape) sequence is re-run and the next token is
//! sampled from the logits at the last attended position. (No KV cache:
//! the AOT artifact has a fixed [1, seq] signature; re-running the full
//! forward keeps the Rust side trivially correct. The device-simulated
//! numbers in Table 1 are per-forward, matching the paper's setup.)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::compiler::exec::{ExecError, Feeds, QuantizedWeights};
use crate::compiler::{compile, CompileOptions, Compiled};
use crate::compress::{compress_encoder, CompressionConfig, CompressionReport};
use crate::model::{build_encoder, BertConfig};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Executable, Runtime};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub text: String,
    pub tokens_generated: usize,
    /// Per-token forward latencies (for the demo's tokens/s display).
    pub per_token_ms: Vec<f64>,
}

pub struct GenEngine {
    pub tokenizer: Arc<Tokenizer>,
    exe: Arc<Executable>,
    /// Device-resident parameters, uploaded once (§Perf).
    params: Vec<xla::PjRtBuffer>,
    pub seq: usize,
    pub vocab: usize,
}

impl GenEngine {
    pub fn new(rt: &mut Runtime, tokenizer: Arc<Tokenizer>) -> Result<Self> {
        let exe = rt.load("gen_b1")?;
        let params = rt.load_params_buffers("gen")?;
        let seq = rt.manifest.models["gen"].cfg("seq");
        let vocab = rt.manifest.models["gen"].cfg("vocab");
        Ok(GenEngine { tokenizer, exe, params, seq, vocab })
    }

    /// Replace parameters (e.g. after LM fine-tuning via crate::train):
    /// uploads the trained literals to the device once.
    pub fn set_params(&mut self, rt: &Runtime, params: &[xla::Literal]) -> anyhow::Result<()> {
        self.params =
            params.iter().map(|l| rt.upload(l)).collect::<anyhow::Result<Vec<_>>>()?;
        Ok(())
    }

    pub fn generate(&self, req: &GenRequest) -> Result<GenResponse> {
        let mut rng = Rng::new(req.seed);
        let mut ids: Vec<i32> = self
            .tokenizer
            .encode(&req.prompt)
            .iter()
            .map(|&t| (t as i32).min(self.vocab as i32 - 1))
            .collect();
        if ids.is_empty() {
            ids.push(crate::tokenizer::CLS as i32);
        }
        if ids.len() >= self.seq {
            ids.truncate(self.seq - 1);
        }

        let mut per_token_ms = Vec::new();
        let mut generated = 0usize;
        while generated < req.max_new_tokens && ids.len() < self.seq {
            let used = ids.len();
            let mut padded = ids.clone();
            padded.resize(self.seq, 0);
            let mut mask = vec![0.0f32; self.seq];
            for m in mask.iter_mut().take(used) {
                *m = 1.0;
            }
            let t0 = std::time::Instant::now();
            let out = self.exe.run_device(
                &self.params,
                &[lit_i32(&padded, &[1, self.seq])?, lit_f32(&mask, &[1, self.seq])?],
            )?;
            per_token_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            let logits = to_vec_f32(&out[0])?; // [1, seq, vocab]
            let last = &logits[(used - 1) * self.vocab..used * self.vocab];
            let next = rng.sample_logits(last, req.temperature) as i32;
            ids.push(next);
            generated += 1;
        }

        let text = self
            .tokenizer
            .decode(&ids.iter().map(|&i| i as u32).collect::<Vec<_>>());
        Ok(GenResponse { text, tokens_generated: generated, per_token_ms })
    }
}

// ---- native backend -----------------------------------------------------

/// Append the LM head to an encoder graph: each position's hidden state
/// projects to vocabulary logits.
fn lm_head(g: &mut crate::compiler::ir::Graph, cfg: &BertConfig) {
    let x = *g.outputs.last().expect("encoder output");
    let w = g.weight("lm/w_head", &[cfg.hidden, cfg.vocab]);
    let logits = g.matmul(x, w); // [seq, vocab]
    // Logits are the only output (see qa_graph: a retained hidden-state
    // output would be copied per step and never freed by the arena).
    g.outputs.clear();
    g.mark_output(logits);
}

/// The dense generation graph (encoder + LM head).
fn lm_graph(cfg: &BertConfig) -> crate::compiler::ir::Graph {
    let mut g = build_encoder(cfg);
    lm_head(&mut g, cfg);
    g
}

/// PJRT-free text-generation engine with the same request/response types
/// and decode loop as [`GenEngine`]: at each step the full static-shape
/// sequence is re-run on the wave-parallel arena executor (cached
/// `PreparedExec`, weights borrowed — not copied — per step; optionally
/// pruned/int8 via the `compress` subsystem) and the next token is
/// sampled from the logits at the last attended position.
/// (Bidirectional attention over the attended prefix — this mirrors the
/// AOT `gen_b1` interface and timing shape, not its causal mask.)
pub struct NativeGenEngine {
    pub tokenizer: Arc<Tokenizer>,
    compiled: Compiled,
    weights: HashMap<String, Vec<f32>>,
    quant: Option<QuantizedWeights>,
    cfg: BertConfig,
    /// What compression this engine serves.
    pub compression: CompressionConfig,
    pub report: CompressionReport,
    /// Worker threads per forward in the wave executor.
    pub threads: usize,
}

impl NativeGenEngine {
    pub fn new(tokenizer: Arc<Tokenizer>, cfg: BertConfig, threads: usize) -> Self {
        Self::with_compression(tokenizer, cfg, threads, CompressionConfig::none())
    }

    /// As [`NativeQaEngine::with_compression`](super::NativeQaEngine):
    /// dense weight draw, structured pruning (graph + weights together),
    /// compile, then int8 table from the compiled model.
    pub fn with_compression(
        tokenizer: Arc<Tokenizer>,
        cfg: BertConfig,
        threads: usize,
        compression: CompressionConfig,
    ) -> Self {
        let dense = lm_graph(&cfg);
        let mut weights = super::init_weights(&dense, 0x6E6E_57A7);
        let (mut g, mut report) = compress_encoder(&cfg, &mut weights, &compression);
        lm_head(&mut g, &cfg);
        let compiled = compile(
            &g,
            &CompileOptions { model_only_tuning: true, compression, ..Default::default() },
        );
        let quant = compression.int8.then(|| compiled.quantize_weights(&weights));
        if compression.int8 {
            // The compiled model also quantizes the LM head, which the
            // encoder-level report couldn't see.
            report.quantized_params = compiled
                .quant_sites
                .iter()
                .filter_map(|s| weights.get(&s.name))
                .map(|v| v.len())
                .sum();
        }
        NativeGenEngine {
            tokenizer,
            compiled,
            weights,
            quant,
            cfg,
            compression,
            report,
            threads: threads.max(1),
        }
    }

    /// Small default configuration for demos and benches.
    pub fn demo(tokenizer: Arc<Tokenizer>, threads: usize) -> Self {
        let cfg = BertConfig { vocab: 2048, seq: 64, layers: 2, hidden: 128, heads: 4, inter: 512 };
        Self::new(tokenizer, cfg, threads)
    }

    pub fn generate(&self, req: &GenRequest) -> Result<GenResponse, ExecError> {
        let (seq, vocab) = (self.cfg.seq, self.cfg.vocab);
        let mut rng = Rng::new(req.seed);
        let mut ids: Vec<i32> = self
            .tokenizer
            .encode(&req.prompt)
            .iter()
            .map(|&t| (t as i32).min(vocab as i32 - 1))
            .collect();
        if ids.is_empty() {
            ids.push(crate::tokenizer::CLS as i32);
        }
        if ids.len() >= seq {
            ids.truncate(seq - 1);
        }

        let mut per_token_ms = Vec::new();
        let mut generated = 0usize;
        // Weights are loop-invariant and live in the persistent map the
        // executor borrows; only input_ids/mask go in the request layer.
        let mut request: HashMap<String, Vec<f32>> = HashMap::new();
        while generated < req.max_new_tokens && ids.len() < seq {
            let used = ids.len();
            let mut padded: Vec<f32> = ids.iter().map(|&i| i as f32).collect();
            padded.resize(seq, 0.0);
            request.insert("input_ids".to_string(), padded);
            let mask: Vec<f32> = (0..seq)
                .map(|i| if i < used { 0.0 } else { super::NEG_MASK })
                .collect();
            for l in 0..self.cfg.layers {
                request.insert(format!("mask{l}"), mask.clone());
            }

            let t0 = std::time::Instant::now();
            let (outs, _) = self.compiled.run_parallel_with(
                &Feeds::layered(&request, &self.weights),
                self.threads,
                self.quant.as_ref(),
            )?;
            per_token_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            let logits = outs.last().expect("lm graph has outputs"); // [seq, vocab]
            let last = &logits.data[(used - 1) * vocab..used * vocab];
            let next = rng.sample_logits(last, req.temperature) as i32;
            ids.push(next.min(vocab as i32 - 1));
            generated += 1;
        }

        let text = self
            .tokenizer
            .decode(&ids.iter().map(|&i| i as u32).collect::<Vec<_>>());
        Ok(GenResponse { text, tokens_generated: generated, per_token_ms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::{Tokenizer, Vocab};

    fn tiny_engine(threads: usize) -> NativeGenEngine {
        let corpus = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word .";
        let tok = Arc::new(Tokenizer::new(Vocab::build(corpus, 256)));
        let cfg = BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
        NativeGenEngine::new(tok, cfg, threads)
    }

    #[test]
    fn native_generation_is_deterministic_across_threads() {
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 11,
        };
        let r1 = tiny_engine(1).generate(&req).unwrap();
        let r2 = tiny_engine(4).generate(&req).unwrap();
        assert_eq!(r1.tokens_generated, 4);
        assert_eq!(r1.text, r2.text, "wave executor must not change sampling");
        assert_eq!(r1.per_token_ms.len(), 4);
    }

    #[test]
    fn compressed_generation_is_deterministic_and_smaller() {
        let corpus = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word .";
        let mk = |threads: usize| {
            let tok = Arc::new(Tokenizer::new(Vocab::build(corpus, 256)));
            let cfg =
                BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
            NativeGenEngine::with_compression(
                tok,
                cfg,
                threads,
                CompressionConfig::pruned_int8(0.5, 0.5),
            )
        };
        let req = GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 3,
            temperature: 0.0,
            seed: 11,
        };
        let e1 = mk(1);
        assert!(e1.report.params_after < e1.report.params_before);
        assert!(e1.report.size_ratio() > 1.5, "{}", e1.report.size_ratio());
        let r1 = e1.generate(&req).unwrap();
        let r4 = mk(4).generate(&req).unwrap();
        assert_eq!(r1.text, r4.text, "compressed decode must not depend on threads");
        assert_eq!(r1.tokens_generated, 3);
    }

    #[test]
    fn native_generation_respects_sequence_cap() {
        let req = GenRequest {
            prompt: "the quick brown fox jumps over the lazy dog".into(),
            max_new_tokens: 64,
            temperature: 0.5,
            seed: 3,
        };
        let r = tiny_engine(2).generate(&req).unwrap();
        assert!(r.tokens_generated < 64, "seq cap must stop generation");
    }
}
