//! Text-generation engine — the paper's Fig. 1 (right) demo: "given a
//! starting sentence, it can automatically generate new sentences by
//! word."
//!
//! Autoregressive decode over the causal-LM executable (gen_b1): at each
//! step the full (static-shape) sequence is re-run and the next token is
//! sampled from the logits at the last attended position. (No KV cache:
//! the AOT artifact has a fixed [1, seq] signature; re-running the full
//! forward keeps the Rust side trivially correct. The device-simulated
//! numbers in Table 1 are per-forward, matching the paper's setup.)

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Executable, Runtime};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub text: String,
    pub tokens_generated: usize,
    /// Per-token forward latencies (for the demo's tokens/s display).
    pub per_token_ms: Vec<f64>,
}

pub struct GenEngine {
    pub tokenizer: Arc<Tokenizer>,
    exe: Arc<Executable>,
    /// Device-resident parameters, uploaded once (§Perf).
    params: Vec<xla::PjRtBuffer>,
    pub seq: usize,
    pub vocab: usize,
}

impl GenEngine {
    pub fn new(rt: &mut Runtime, tokenizer: Arc<Tokenizer>) -> Result<Self> {
        let exe = rt.load("gen_b1")?;
        let params = rt.load_params_buffers("gen")?;
        let seq = rt.manifest.models["gen"].cfg("seq");
        let vocab = rt.manifest.models["gen"].cfg("vocab");
        Ok(GenEngine { tokenizer, exe, params, seq, vocab })
    }

    /// Replace parameters (e.g. after LM fine-tuning via crate::train):
    /// uploads the trained literals to the device once.
    pub fn set_params(&mut self, rt: &Runtime, params: &[xla::Literal]) -> anyhow::Result<()> {
        self.params =
            params.iter().map(|l| rt.upload(l)).collect::<anyhow::Result<Vec<_>>>()?;
        Ok(())
    }

    pub fn generate(&self, req: &GenRequest) -> Result<GenResponse> {
        let mut rng = Rng::new(req.seed);
        let mut ids: Vec<i32> = self
            .tokenizer
            .encode(&req.prompt)
            .iter()
            .map(|&t| (t as i32).min(self.vocab as i32 - 1))
            .collect();
        if ids.is_empty() {
            ids.push(crate::tokenizer::CLS as i32);
        }
        if ids.len() >= self.seq {
            ids.truncate(self.seq - 1);
        }

        let mut per_token_ms = Vec::new();
        let mut generated = 0usize;
        while generated < req.max_new_tokens && ids.len() < self.seq {
            let used = ids.len();
            let mut padded = ids.clone();
            padded.resize(self.seq, 0);
            let mut mask = vec![0.0f32; self.seq];
            for m in mask.iter_mut().take(used) {
                *m = 1.0;
            }
            let t0 = std::time::Instant::now();
            let out = self.exe.run_device(
                &self.params,
                &[lit_i32(&padded, &[1, self.seq])?, lit_f32(&mask, &[1, self.seq])?],
            )?;
            per_token_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            let logits = to_vec_f32(&out[0])?; // [1, seq, vocab]
            let last = &logits[(used - 1) * self.vocab..used * self.vocab];
            let next = rng.sample_logits(last, req.temperature) as i32;
            ids.push(next);
            generated += 1;
        }

        let text = self
            .tokenizer
            .decode(&ids.iter().map(|&i| i as u32).collect::<Vec<_>>());
        Ok(GenResponse { text, tokens_generated: generated, per_token_ms })
    }
}
