//! Request-scoped tracing for the serving stack.
//!
//! Where `compiler/exec/profile.rs` answers "which kernel is slow?",
//! this module answers "which *phase* of which *request* was slow?" —
//! the continuous batcher interleaves many sessions per step wave, so a
//! p99 outlier can lose its budget to queue wait, admission prefill,
//! co-resident sessions sharing its waves, page-pool pressure, or the
//! final sample/retire hop, and fleet-level histograms cannot say which.
//!
//! Design rules, inherited from the execution profiler:
//!
//! * **Zero overhead when off.** Tracing is opt-in via
//!   `Option<Arc<Tracer>>`; with no tracer attached, the serving path
//!   allocates nothing, takes no locks, and reads no clocks on behalf of
//!   tracing (every timing site is gated on [`armed`]).
//! * **Lock-free recording.** Spans accumulate in a [`RequestTrace`]
//!   owned by exactly one pipeline stage at a time (it travels inside
//!   the batcher's job / the scheduler's session), so recording is plain
//!   `Vec::push` with no synchronization. Aggregate phase counters are
//!   the lock-free [`StreamingHistogram`]s from `serving/metrics`. The
//!   only lock is a short critical section around the tail-retention
//!   ring, taken once per *retired* request.
//! * **Traced == untraced.** Tracing never touches model state, RNG
//!   state, or execution order — traced runs are bitwise identical to
//!   untraced runs (pinned in `tests/trace.rs` alongside the decode
//!   differential pins).
//!
//! ## Span model
//!
//! Every request gets a `request_id` and a span tree:
//!
//! ```text
//! request ─ queue_wait → admit(prefill, sample) → step_wave[n] → retire
//! ```
//!
//! Step-wave spans carry the dispatched rung width (`occupancy`) and the
//! number of co-resident real sessions, so time lost to sharing a wave
//! is attributable. Page-pool checkouts/exhaustions and batcher faults
//! are recorded as instant events on the same timeline.
//!
//! ## Tail-based sampling
//!
//! Aggregates (per-phase latency histograms) are recorded for every
//! traced request; *full span trees* are retained only for the slowest
//! percentile ([`TraceConfig::tail_pct`]) and for errored requests, in a
//! fixed-size ring ([`TraceConfig::ring`]) that evicts the fastest
//! non-errored entry first — bounded memory under unbounded traffic.
//!
//! ## Export
//!
//! [`TraceReport::json`] is the machine-readable form (published as
//! `BENCH_trace.json`); [`TraceReport::chrome_events`] renders retained
//! requests as per-request lanes that merge with the kernel profiler's
//! chrome trace via `ProfileReport::chrome_trace_with` — one timeline,
//! openable in `ui.perfetto.dev` (`canao serve-load --trace-out` /
//! `canao trace`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::metrics::{Counter, StreamingHistogram};
use crate::util::json::Json;

/// Request lanes in the merged chrome trace start at this tid (kernel
/// lanes use tids below 99, the wave lane uses 99).
pub const REQUEST_LANE_BASE: u64 = 100;

/// One phase of a request's lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Submit → the worker picking the request up.
    QueueWait,
    /// Admission into the scheduler (encode + cache checkout + prefill).
    Admit,
    /// The prompt prefill forward.
    Prefill,
    /// One batched decode wave this request took part in.
    StepWave,
    /// Sampling the next token from the logits row.
    Sample,
    /// Retirement: detokenize, reply, return pages.
    Retire,
    /// Generic batch execution (the dynamic batcher's `run_batch`).
    Run,
}

impl Phase {
    pub const ALL: [Phase; 7] = [
        Phase::QueueWait,
        Phase::Admit,
        Phase::Prefill,
        Phase::StepWave,
        Phase::Sample,
        Phase::Retire,
        Phase::Run,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Phase::QueueWait => "queue_wait",
            Phase::Admit => "admit",
            Phase::Prefill => "prefill",
            Phase::StepWave => "step_wave",
            Phase::Sample => "sample",
            Phase::Retire => "retire",
            Phase::Run => "run",
        }
    }

    fn idx(&self) -> usize {
        match self {
            Phase::QueueWait => 0,
            Phase::Admit => 1,
            Phase::Prefill => 2,
            Phase::StepWave => 3,
            Phase::Sample => 4,
            Phase::Retire => 5,
            Phase::Run => 6,
        }
    }
}

/// One recorded span (times are ns relative to the tracer's epoch).
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub phase: Phase,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Step waves: the dispatched rung width (batch slots incl. dummies).
    pub occupancy: u32,
    /// Step waves: real co-resident sessions sharing the wave.
    pub co_resident: u32,
}

/// Instant events recorded on a request's timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// KV pages checked out at admission (pool utilization after).
    PagePoolCheckout { in_use: usize, capacity: Option<usize> },
    /// Admission failed: the pool could not seat the session.
    PagePoolExhausted { in_use: usize, capacity: usize },
    /// A batcher/scheduler fault hit this request.
    BatcherFault { kind: &'static str },
}

impl EventKind {
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::PagePoolCheckout { .. } => "page_pool_checkout",
            EventKind::PagePoolExhausted { .. } => "page_pool_exhausted",
            EventKind::BatcherFault { .. } => "batcher_fault",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub at_ns: u64,
    pub kind: EventKind,
}

/// Tracer configuration (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Max retained full span trees.
    pub ring: usize,
    /// Retain span trees for requests at or above this total-latency
    /// percentile (plus every errored request).
    pub tail_pct: f64,
    /// Record detailed spans for every Nth request (1 = all). Requests
    /// sampled out still count toward request/error totals and the
    /// total-latency histogram.
    pub sample_every: u64,
    /// Tail decisions need at least this many completed requests; below
    /// it every detailed request qualifies (so short runs retain data).
    pub min_tail_samples: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { ring: 32, tail_pct: 95.0, sample_every: 1, min_tail_samples: 16 }
    }
}

/// A retained full span tree (one tail-sampled or errored request).
#[derive(Debug, Clone)]
pub struct RetainedTrace {
    pub id: u64,
    pub start_ns: u64,
    pub total_ns: u64,
    pub error: bool,
    pub spans: Vec<Span>,
    pub events: Vec<TraceEvent>,
}

impl RetainedTrace {
    /// Total ns recorded under `phase`.
    pub fn phase_ns(&self, phase: Phase) -> u64 {
        self.spans.iter().filter(|s| s.phase == phase).map(|s| s.dur_ns).sum()
    }
}

/// The collector. Create once, share via `Arc` with every batcher /
/// scheduler that should report into it.
pub struct Tracer {
    t0: Instant,
    cfg: TraceConfig,
    next_id: AtomicU64,
    requests: Counter,
    detailed: Counter,
    errors: Counter,
    total_us: StreamingHistogram,
    phase_us: [StreamingHistogram; Phase::ALL.len()],
    ring: Mutex<Vec<RetainedTrace>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("cfg", &self.cfg)
            .field("requests", &self.requests.get())
            .finish_non_exhaustive()
    }
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Tracer {
        Tracer {
            t0: Instant::now(),
            cfg,
            next_id: AtomicU64::new(0),
            requests: Counter::default(),
            detailed: Counter::default(),
            errors: Counter::default(),
            total_us: StreamingHistogram::new(),
            phase_us: std::array::from_fn(|_| StreamingHistogram::new()),
            ring: Mutex::new(Vec::new()),
        }
    }

    pub fn shared(cfg: TraceConfig) -> Arc<Tracer> {
        Arc::new(Tracer::new(cfg))
    }

    fn rel_ns(&self, at: Instant) -> u64 {
        at.duration_since(self.t0).as_nanos() as u64
    }

    /// Open a trace for a new request. Allocates the span buffer only
    /// when this request is head-sampled for detailed recording.
    pub fn start_request(self: &Arc<Self>) -> RequestTrace {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let every = self.cfg.sample_every.max(1);
        let detailed = id % every == 0;
        let born = Instant::now();
        RequestTrace {
            tracer: Arc::clone(self),
            id,
            born,
            born_ns: self.rel_ns(born),
            detailed,
            done: false,
            spans: if detailed { Vec::with_capacity(8) } else { Vec::new() },
            events: Vec::new(),
        }
    }

    /// Fold a finished request into the aggregates and decide retention.
    fn retire(&self, rt: RetainedTrace, detailed: bool) {
        self.requests.inc();
        if rt.error {
            self.errors.inc();
        }
        self.total_us.record_value(rt.total_ns / 1_000);
        if !detailed {
            return;
        }
        self.detailed.inc();
        for s in &rt.spans {
            self.phase_us[s.phase.idx()].record_value(s.dur_ns / 1_000);
        }
        // `percentile_value` reports a bucket midpoint, which can sit
        // above the just-recorded value even when that value IS the
        // percentile sample — allow one bucket of tolerance (the
        // histogram's stated <= 1/8 relative error).
        let total_us = rt.total_ns / 1_000;
        let n = self.total_us.len();
        let slow = n <= self.cfg.min_tail_samples
            || total_us + StreamingHistogram::bucket_width(total_us)
                >= self.total_us.percentile_value(self.cfg.tail_pct);
        if !(rt.error || slow) || self.cfg.ring == 0 {
            return;
        }
        let mut ring = self.ring.lock().expect("trace ring poisoned");
        if ring.len() < self.cfg.ring {
            ring.push(rt);
            return;
        }
        // Full: evict the fastest non-errored entry (errors out-rank
        // latency), but only for a slower/more-important newcomer.
        if let Some((i, weakest)) = ring
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| (r.error, r.total_ns))
            .map(|(i, r)| (i, (r.error, r.total_ns)))
        {
            if (rt.error, rt.total_ns) > weakest {
                ring[i] = rt;
            }
        }
    }

    /// Snapshot everything recorded so far.
    pub fn report(&self) -> TraceReport {
        let mut retained = self.ring.lock().expect("trace ring poisoned").clone();
        retained.sort_by(|a, b| (b.error, b.total_ns).cmp(&(a.error, a.total_ns)));
        let phases = Phase::ALL
            .iter()
            .map(|p| {
                let h = &self.phase_us[p.idx()];
                PhaseSummary {
                    phase: *p,
                    count: h.len(),
                    p50_us: h.percentile_value(50.0),
                    p95_us: h.percentile_value(95.0),
                    p99_us: h.percentile_value(99.0),
                    max_us: h.max_value(),
                    mean_us: h.mean_value(),
                }
            })
            .collect();
        TraceReport {
            requests: self.requests.get(),
            detailed: self.detailed.get(),
            errors: self.errors.get(),
            tail_pct: self.cfg.tail_pct,
            total_p50_us: self.total_us.percentile_value(50.0),
            total_p95_us: self.total_us.percentile_value(95.0),
            total_p99_us: self.total_us.percentile_value(99.0),
            phases,
            retained,
        }
    }
}

/// The per-request recorder. Owned by exactly one pipeline stage at a
/// time; recording is plain appends, no locks. Dropping an unfinished
/// trace (lost request, worker panic unwinding past it) retires it as
/// an error so faults are never silently invisible.
pub struct RequestTrace {
    tracer: Arc<Tracer>,
    pub id: u64,
    born: Instant,
    born_ns: u64,
    detailed: bool,
    done: bool,
    spans: Vec<Span>,
    events: Vec<TraceEvent>,
}

/// True when `t` carries a detail-sampled trace — the gate every caller
/// must use before reading a clock on tracing's behalf.
pub fn armed(t: &Option<RequestTrace>) -> bool {
    t.as_ref().is_some_and(|t| t.detailed)
}

impl RequestTrace {
    pub fn detailed(&self) -> bool {
        self.detailed
    }

    /// Record `phase` from `start` until now.
    pub fn span_from(&mut self, phase: Phase, start: Instant) {
        let dur = start.elapsed().as_nanos() as u64;
        self.span_at(phase, start, dur, 0, 0);
    }

    /// Record `phase` at an explicit start/duration (used when the
    /// caller already measured the window, e.g. the shared wave timer).
    pub fn span_at(
        &mut self,
        phase: Phase,
        start: Instant,
        dur_ns: u64,
        occupancy: u32,
        co_resident: u32,
    ) {
        if !self.detailed {
            return;
        }
        let start_ns = self.tracer.rel_ns(start);
        self.spans.push(Span { phase, start_ns, dur_ns, occupancy, co_resident });
    }

    /// Close the queue-wait span: birth (submit time) until `now`.
    pub fn queue_wait_until(&mut self, now: Instant) {
        let dur = now.duration_since(self.born).as_nanos() as u64;
        self.span_at(Phase::QueueWait, self.born, dur, 0, 0);
    }

    /// Record an instant event at the current time.
    pub fn event(&mut self, kind: EventKind) {
        if !self.detailed {
            return;
        }
        self.events.push(TraceEvent { at_ns: self.tracer.rel_ns(Instant::now()), kind });
    }

    fn retire(&mut self, error: bool) {
        if self.done {
            return;
        }
        self.done = true;
        let total_ns = self.born.elapsed().as_nanos() as u64;
        let rt = RetainedTrace {
            id: self.id,
            start_ns: self.born_ns,
            total_ns,
            error,
            spans: std::mem::take(&mut self.spans),
            events: std::mem::take(&mut self.events),
        };
        let tracer = Arc::clone(&self.tracer);
        tracer.retire(rt, self.detailed);
    }

    /// Finish the request (the root span closes now). `error` marks the
    /// trace for unconditional tail retention.
    pub fn finish(mut self, error: bool) {
        self.retire(error);
    }
}

impl Drop for RequestTrace {
    fn drop(&mut self) {
        self.retire(true);
    }
}

/// Aggregate latency for one phase across every detailed request.
#[derive(Debug, Clone)]
pub struct PhaseSummary {
    pub phase: Phase,
    pub count: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
}

/// Snapshot of a [`Tracer`]: aggregates plus the retained tail.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub requests: u64,
    pub detailed: u64,
    pub errors: u64,
    pub tail_pct: f64,
    pub total_p50_us: u64,
    pub total_p95_us: u64,
    pub total_p99_us: u64,
    pub phases: Vec<PhaseSummary>,
    pub retained: Vec<RetainedTrace>,
}

fn us(ns: u64) -> Json {
    Json::Num(ns as f64 / 1000.0)
}

impl TraceReport {
    /// Machine-readable form (published as `BENCH_trace.json`). Schema
    /// is pinned by `tests/trace.rs`.
    pub fn json(&self) -> Json {
        let mut phases = BTreeMap::new();
        for p in &self.phases {
            let mut m = BTreeMap::new();
            m.insert("count".to_string(), Json::Num(p.count as f64));
            m.insert("p50_us".to_string(), Json::Num(p.p50_us as f64));
            m.insert("p95_us".to_string(), Json::Num(p.p95_us as f64));
            m.insert("p99_us".to_string(), Json::Num(p.p99_us as f64));
            m.insert("max_us".to_string(), Json::Num(p.max_us as f64));
            m.insert("mean_us".to_string(), Json::Num(p.mean_us));
            phases.insert(p.phase.label().to_string(), Json::Obj(m));
        }
        let retained: Vec<Json> = self.retained.iter().map(Self::retained_json).collect();
        let mut top = BTreeMap::new();
        top.insert("schema".to_string(), Json::Num(1.0));
        top.insert("bench".to_string(), Json::Str("trace".to_string()));
        top.insert("requests".to_string(), Json::Num(self.requests as f64));
        top.insert("detailed".to_string(), Json::Num(self.detailed as f64));
        top.insert("errors".to_string(), Json::Num(self.errors as f64));
        top.insert("tail_pct".to_string(), Json::Num(self.tail_pct));
        top.insert("total_p50_us".to_string(), Json::Num(self.total_p50_us as f64));
        top.insert("total_p95_us".to_string(), Json::Num(self.total_p95_us as f64));
        top.insert("total_p99_us".to_string(), Json::Num(self.total_p99_us as f64));
        top.insert("phases".to_string(), Json::Obj(phases));
        top.insert("retained".to_string(), Json::Arr(retained));
        Json::Obj(top)
    }

    fn retained_json(rt: &RetainedTrace) -> Json {
        let spans: Vec<Json> = rt
            .spans
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("phase".to_string(), Json::Str(s.phase.label().to_string()));
                m.insert("start_us".to_string(), us(s.start_ns));
                m.insert("dur_us".to_string(), us(s.dur_ns));
                m.insert("occupancy".to_string(), Json::Num(s.occupancy as f64));
                m.insert("co_resident".to_string(), Json::Num(s.co_resident as f64));
                Json::Obj(m)
            })
            .collect();
        let events: Vec<Json> = rt
            .events
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("at_us".to_string(), us(e.at_ns));
                m.insert("kind".to_string(), Json::Str(e.kind.label().to_string()));
                match e.kind {
                    EventKind::PagePoolCheckout { in_use, capacity } => {
                        m.insert("in_use".to_string(), Json::Num(in_use as f64));
                        m.insert(
                            "capacity".to_string(),
                            capacity.map_or(Json::Null, |c| Json::Num(c as f64)),
                        );
                    }
                    EventKind::PagePoolExhausted { in_use, capacity } => {
                        m.insert("in_use".to_string(), Json::Num(in_use as f64));
                        m.insert("capacity".to_string(), Json::Num(capacity as f64));
                    }
                    EventKind::BatcherFault { kind } => {
                        m.insert("fault".to_string(), Json::Str(kind.to_string()));
                    }
                }
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Num(rt.id as f64));
        m.insert("error".to_string(), Json::Bool(rt.error));
        m.insert("start_us".to_string(), us(rt.start_ns));
        m.insert("total_us".to_string(), us(rt.total_ns));
        m.insert("spans".to_string(), Json::Arr(spans));
        m.insert("events".to_string(), Json::Arr(events));
        Json::Obj(m)
    }

    /// Chrome-trace events for the retained requests: one lane (tid)
    /// per request starting at [`REQUEST_LANE_BASE`], a root "X" event
    /// covering the whole request, child "X" events per span, and "i"
    /// instant events. Merge into a kernel profile's timeline with
    /// `ProfileReport::chrome_trace_with`, or wrap standalone via
    /// [`TraceReport::chrome_trace`].
    pub fn chrome_events(&self) -> Vec<Json> {
        let mut events = Vec::new();
        for (i, rt) in self.retained.iter().enumerate() {
            let tid = Json::Num((REQUEST_LANE_BASE + i as u64) as f64);
            let mut root = BTreeMap::new();
            root.insert("name".to_string(), Json::Str(format!("request {}", rt.id)));
            root.insert("ph".to_string(), Json::Str("X".to_string()));
            root.insert("ts".to_string(), us(rt.start_ns));
            root.insert("dur".to_string(), us(rt.total_ns));
            root.insert("pid".to_string(), Json::Num(0.0));
            root.insert("tid".to_string(), tid.clone());
            let mut args = BTreeMap::new();
            args.insert("request_id".to_string(), Json::Num(rt.id as f64));
            args.insert("error".to_string(), Json::Bool(rt.error));
            root.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(root));
            for s in &rt.spans {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(s.phase.label().to_string()));
                m.insert("ph".to_string(), Json::Str("X".to_string()));
                m.insert("ts".to_string(), us(s.start_ns));
                m.insert("dur".to_string(), us(s.dur_ns));
                m.insert("pid".to_string(), Json::Num(0.0));
                m.insert("tid".to_string(), tid.clone());
                let mut args = BTreeMap::new();
                args.insert("request_id".to_string(), Json::Num(rt.id as f64));
                if s.phase == Phase::StepWave {
                    args.insert("occupancy".to_string(), Json::Num(s.occupancy as f64));
                    args.insert("co_resident".to_string(), Json::Num(s.co_resident as f64));
                }
                m.insert("args".to_string(), Json::Obj(args));
                events.push(Json::Obj(m));
            }
            for e in &rt.events {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.kind.label().to_string()));
                m.insert("ph".to_string(), Json::Str("i".to_string()));
                m.insert("s".to_string(), Json::Str("t".to_string()));
                m.insert("ts".to_string(), us(e.at_ns));
                m.insert("pid".to_string(), Json::Num(0.0));
                m.insert("tid".to_string(), tid.clone());
                events.push(Json::Obj(m));
            }
        }
        events
    }

    /// Standalone chrome-trace document (request lanes only) in the
    /// same envelope the kernel profiler emits.
    pub fn chrome_trace(&self) -> Json {
        let mut top = BTreeMap::new();
        top.insert("traceEvents".to_string(), Json::Arr(self.chrome_events()));
        top.insert("displayTimeUnit".to_string(), Json::Str("ns".to_string()));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn finish_one(tracer: &Arc<Tracer>, spin: Duration, error: bool) -> u64 {
        let mut t = tracer.start_request();
        let id = t.id;
        let t0 = Instant::now();
        std::thread::sleep(spin);
        t.span_from(Phase::Prefill, t0);
        t.finish(error);
        id
    }

    #[test]
    fn ring_is_bounded_and_keeps_slowest() {
        let tracer = Tracer::shared(TraceConfig {
            ring: 2,
            tail_pct: 0.0, // everything qualifies; the ring must bound it
            sample_every: 1,
            min_tail_samples: 1,
        });
        for ms in [1u64, 5, 2, 4, 3] {
            finish_one(&tracer, Duration::from_millis(ms), false);
        }
        let rep = tracer.report();
        assert_eq!(rep.requests, 5);
        assert_eq!(rep.retained.len(), 2, "ring bound");
        // The two slowest (5ms, 4ms) survive; report sorts slowest first.
        assert!(rep.retained[0].total_ns >= rep.retained[1].total_ns);
        assert!(rep.retained[1].total_ns >= 3_000_000, "kept the slow tail");
    }

    #[test]
    fn errors_are_always_retained() {
        let tracer = Tracer::shared(TraceConfig {
            ring: 1,
            tail_pct: 0.0,
            sample_every: 1,
            min_tail_samples: 1,
        });
        finish_one(&tracer, Duration::from_millis(8), false);
        let err_id = finish_one(&tracer, Duration::from_millis(1), true);
        let rep = tracer.report();
        assert_eq!(rep.errors, 1);
        assert_eq!(rep.retained.len(), 1);
        assert_eq!(rep.retained[0].id, err_id, "error evicts the faster-but-clean entry");
        assert!(rep.retained[0].error);
    }

    #[test]
    fn head_sampling_gates_detail_but_counts_everything() {
        let tracer = Tracer::shared(TraceConfig {
            ring: 8,
            tail_pct: 0.0,
            sample_every: 2,
            min_tail_samples: 1,
        });
        for _ in 0..4 {
            let mut t = tracer.start_request();
            assert_eq!(t.detailed(), t.id % 2 == 0);
            let t0 = Instant::now();
            t.span_from(Phase::Admit, t0);
            t.finish(false);
        }
        let rep = tracer.report();
        assert_eq!(rep.requests, 4);
        assert_eq!(rep.detailed, 2);
        assert_eq!(rep.retained.len(), 2, "only detailed requests retain span trees");
    }

    #[test]
    fn dropped_trace_retires_as_error() {
        let tracer = Tracer::shared(TraceConfig {
            ring: 4,
            tail_pct: 0.0,
            sample_every: 1,
            min_tail_samples: 1,
        });
        drop(tracer.start_request());
        let rep = tracer.report();
        assert_eq!(rep.requests, 1);
        assert_eq!(rep.errors, 1);
        assert_eq!(rep.retained.len(), 1);
    }

    #[test]
    fn wave_spans_carry_occupancy() {
        let tracer = Tracer::shared(TraceConfig::default());
        let mut t = tracer.start_request();
        t.span_at(Phase::StepWave, Instant::now(), 1_000, 4, 3);
        t.event(EventKind::PagePoolCheckout { in_use: 2, capacity: Some(8) });
        t.finish(false);
        let rep = tracer.report();
        let rt = &rep.retained[0];
        assert_eq!(rt.phase_ns(Phase::StepWave), 1_000);
        let w = rt.spans.iter().find(|s| s.phase == Phase::StepWave).unwrap();
        assert_eq!((w.occupancy, w.co_resident), (4, 3));
        assert_eq!(rt.events.len(), 1);
    }
}
