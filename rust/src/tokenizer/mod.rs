//! WordPiece tokenizer (S13) — the text front-end of the QA and
//! text-generation demos (Fig. 1 of the paper).
//!
//! Implements the BERT tokenization pipeline: basic whitespace +
//! punctuation pre-tokenization, lowercase, then greedy longest-match
//! WordPiece with `##` continuation pieces. The vocabulary is *built* (not
//! shipped): `Vocab::build` derives pieces from a corpus by frequency —
//! whole words first, then suffix pieces — capped to the embedding size
//! the AOT models were exported with (2048).

use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const UNK: u32 = 1;
pub const CLS: u32 = 2;
pub const SEP: u32 = 3;
pub const MASK: u32 = 4;
pub const SPECIALS: [&str; 5] = ["[PAD]", "[UNK]", "[CLS]", "[SEP]", "[MASK]"];

#[derive(Debug, Clone)]
pub struct Vocab {
    pub id_of: HashMap<String, u32>,
    pub piece_of: Vec<String>,
}

impl Vocab {
    /// Build a vocab from corpus text, capped at `max_size` entries.
    ///
    /// Order: specials, single characters (coverage floor), frequent whole
    /// words, then frequent `##` suffix pieces (2..4 chars) for splitting
    /// unseen words.
    ///
    /// The cap is a hard invariant across ALL phases: token ids index the
    /// embedding table, so a vocab that outgrew `max_size` would gather
    /// out of bounds. A corpus whose character set alone exceeds the cap
    /// is truncated deterministically (chars are sorted, so which survive
    /// is stable); dropped characters tokenize to `[UNK]`.
    pub fn build(corpus: &str, max_size: usize) -> Vocab {
        assert!(
            max_size >= SPECIALS.len(),
            "vocab cap {max_size} cannot hold the {} special tokens",
            SPECIALS.len()
        );
        let mut word_freq: HashMap<String, usize> = HashMap::new();
        let mut char_set: Vec<char> = Vec::new();
        for token in pre_tokenize(corpus) {
            *word_freq.entry(token.clone()).or_default() += 1;
            for c in token.chars() {
                if !char_set.contains(&c) {
                    char_set.push(c);
                }
            }
        }
        char_set.sort();

        // Suffix piece frequencies.
        let mut suffix_freq: HashMap<String, usize> = HashMap::new();
        for (w, f) in &word_freq {
            let chars: Vec<char> = w.chars().collect();
            for start in 1..chars.len() {
                for len in 2..=4usize {
                    if start + len > chars.len() {
                        break;
                    }
                    let piece: String = chars[start..start + len].iter().collect();
                    *suffix_freq.entry(piece).or_default() += f;
                }
            }
        }

        let mut pieces: Vec<String> = SPECIALS.iter().map(|s| s.to_string()).collect();
        // Phase 2: single-character coverage — capped like every other
        // phase (a large corpus charset previously blew past `max_size`
        // here, yielding ids beyond the embedding row count).
        for c in &char_set {
            if pieces.len() >= max_size {
                break;
            }
            pieces.push(c.to_string());
        }
        for c in &char_set {
            if pieces.len() >= max_size {
                break;
            }
            pieces.push(format!("##{c}"));
        }

        // Phase 3: frequent whole words, budgeted to 7/8 of the cap so
        // suffix pieces always get some room.
        let mut words: Vec<(&String, &usize)> = word_freq.iter().collect();
        words.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
        for (w, _) in words {
            if pieces.len() >= max_size * 7 / 8 {
                break;
            }
            if w.chars().count() > 1 && !pieces.contains(w) {
                pieces.push(w.clone());
            }
        }

        // Phase 4: frequent `##` suffix pieces up to the cap.
        let mut sufs: Vec<(&String, &usize)> = suffix_freq.iter().collect();
        sufs.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
        for (s, _) in sufs {
            if pieces.len() >= max_size {
                break;
            }
            let tagged = format!("##{s}");
            if !pieces.contains(&tagged) {
                pieces.push(tagged);
            }
        }
        debug_assert!(pieces.len() <= max_size);

        let id_of = pieces
            .iter()
            .enumerate()
            .map(|(i, p)| (p.clone(), i as u32))
            .collect();
        Vocab { id_of, piece_of: pieces }
    }

    pub fn len(&self) -> usize {
        self.piece_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.piece_of.is_empty()
    }

    /// Save in BERT's one-piece-per-line format.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.piece_of.join("\n"))
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Vocab> {
        let text = std::fs::read_to_string(path)?;
        let piece_of: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        let id_of = piece_of
            .iter()
            .enumerate()
            .map(|(i, p)| (p.clone(), i as u32))
            .collect();
        Ok(Vocab { id_of, piece_of })
    }
}

/// Lowercase + split on whitespace, splitting punctuation into single
/// tokens (BERT's BasicTokenizer).
pub fn pre_tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        if c.is_whitespace() {
            if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
        } else if c.is_ascii_punctuation() {
            if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
            out.push(c.to_string());
        } else {
            cur.extend(c.to_lowercase());
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

pub struct Tokenizer {
    pub vocab: Vocab,
}

impl Tokenizer {
    pub fn new(vocab: Vocab) -> Self {
        Tokenizer { vocab }
    }

    /// Greedy longest-match WordPiece on one pre-token.
    fn wordpiece(&self, word: &str) -> Vec<u32> {
        let chars: Vec<char> = word.chars().collect();
        let mut ids = Vec::new();
        let mut start = 0;
        while start < chars.len() {
            let mut end = chars.len();
            let mut found = None;
            while end > start {
                let piece: String = chars[start..end].iter().collect();
                let candidate = if start == 0 { piece } else { format!("##{piece}") };
                if let Some(&id) = self.vocab.id_of.get(&candidate) {
                    found = Some((id, end));
                    break;
                }
                end -= 1;
            }
            match found {
                Some((id, e)) => {
                    ids.push(id);
                    start = e;
                }
                None => return vec![UNK],
            }
        }
        ids
    }

    /// Tokenize free text to ids (no specials).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        pre_tokenize(text)
            .iter()
            .flat_map(|w| self.wordpiece(w))
            .collect()
    }

    /// BERT pair encoding: [CLS] a [SEP] b [SEP], padded/truncated to
    /// `seq`, with token-type ids and attention mask.
    pub fn encode_pair(
        &self,
        a: &str,
        b: &str,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>, usize) {
        let ta = self.encode(a);
        let tb = self.encode(b);
        let mut ids = vec![CLS as i32];
        let mut tt = vec![0i32];
        for &t in ta.iter().take(seq.saturating_sub(3) / 2) {
            ids.push(t as i32);
            tt.push(0);
        }
        ids.push(SEP as i32);
        tt.push(0);
        let b_start = ids.len();
        for &t in tb.iter().take(seq.saturating_sub(ids.len() + 1)) {
            ids.push(t as i32);
            tt.push(1);
        }
        ids.push(SEP as i32);
        tt.push(1);
        let used = ids.len();
        let mut mask = vec![1.0f32; used];
        ids.resize(seq, PAD as i32);
        tt.resize(seq, 0);
        mask.resize(seq, 0.0);
        (ids, tt, mask, b_start)
    }

    /// Decode ids to text (## pieces joined, specials skipped).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        for &id in ids {
            let piece = self
                .vocab
                .piece_of
                .get(id as usize)
                .map(|s| s.as_str())
                .unwrap_or("[UNK]");
            if SPECIALS.contains(&piece) {
                continue;
            }
            if let Some(cont) = piece.strip_prefix("##") {
                out.push_str(cont);
            } else {
                if !out.is_empty() {
                    out.push(' ');
                }
                out.push_str(piece);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the quick brown fox jumps over the lazy dog. \
        the dog sleeps. a fox is quick and brown. question answering \
        systems read a paragraph and answer a question about the text.";

    fn tok() -> Tokenizer {
        Tokenizer::new(Vocab::build(CORPUS, 512))
    }

    #[test]
    fn specials_have_fixed_ids() {
        let t = tok();
        assert_eq!(t.vocab.id_of["[PAD]"], PAD);
        assert_eq!(t.vocab.id_of["[UNK]"], UNK);
        assert_eq!(t.vocab.id_of["[CLS]"], CLS);
        assert_eq!(t.vocab.id_of["[SEP]"], SEP);
    }

    #[test]
    fn roundtrip_known_words() {
        let t = tok();
        let ids = t.encode("the quick fox");
        assert!(ids.iter().all(|&i| i != UNK));
        assert_eq!(t.decode(&ids), "the quick fox");
    }

    #[test]
    fn unseen_word_splits_into_pieces() {
        let t = tok();
        // "quickest" is unseen but 'quick' + suffix pieces exist.
        let ids = t.encode("quickest");
        assert!(ids.len() >= 2, "{ids:?}");
        assert!(ids.iter().all(|&i| i != UNK));
        assert_eq!(t.decode(&ids), "quickest");
    }

    #[test]
    fn char_coverage_prevents_unk_for_ascii() {
        let t = tok();
        let ids = t.encode("zzzqqq");
        // Characters are in the corpus alphabet? z/q appear in
        // quick/lazy; so full char fallback works.
        assert!(ids.iter().all(|&i| i != UNK), "{ids:?}");
    }

    #[test]
    fn pair_encoding_layout() {
        let t = tok();
        let (ids, tt, mask, b_start) = t.encode_pair("a question", "the text has an answer", 32);
        assert_eq!(ids.len(), 32);
        assert_eq!(ids[0], CLS as i32);
        assert_eq!(tt[0], 0);
        assert!(b_start > 1);
        assert_eq!(tt[b_start], 1);
        let used = mask.iter().filter(|&&m| m > 0.0).count();
        assert!(used < 32);
        assert_eq!(ids[used - 1], SEP as i32);
        assert!(ids[used..].iter().all(|&i| i == PAD as i32));
    }

    #[test]
    fn truncation_respects_seq() {
        let t = tok();
        let long = "the quick brown fox ".repeat(50);
        let (ids, _, mask, _) = t.encode_pair(&long, &long, 16);
        assert_eq!(ids.len(), 16);
        assert_eq!(mask.len(), 16);
    }

    #[test]
    fn vocab_capped_and_saveable() {
        let v = Vocab::build(CORPUS, 64);
        assert!(v.len() <= 64);
        let dir = std::env::temp_dir().join("canao_vocab_test.txt");
        v.save(&dir).unwrap();
        let v2 = Vocab::load(&dir).unwrap();
        assert_eq!(v.piece_of, v2.piece_of);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn huge_charset_respects_cap() {
        // Regression: the char-coverage phases used to push every corpus
        // character (and its ## twin) BEFORE checking max_size, so a
        // many-char corpus produced ids past the embedding row count.
        let corpus: String = (0..300u32)
            .filter_map(|i| char::from_u32(0x3042 + i)) // kana/CJK range
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        let cap = 64;
        let v = Vocab::build(&corpus, cap);
        assert!(v.len() <= cap, "vocab {} exceeds cap {cap}", v.len());
        // Every id a tokenizer can emit stays a valid embedding row.
        let t = Tokenizer::new(v);
        for id in t.encode(&corpus) {
            assert!((id as usize) < cap, "id {id} out of range");
        }
        // Specials survive truncation.
        assert_eq!(t.vocab.id_of["[UNK]"], UNK);
        // Deterministic truncation: same corpus, same vocab.
        let v2 = Vocab::build(&corpus, cap);
        assert_eq!(t.vocab.piece_of, v2.piece_of);
    }

    #[test]
    fn pre_tokenize_punctuation() {
        assert_eq!(
            pre_tokenize("Hello, world!"),
            vec!["hello", ",", "world", "!"]
        );
    }
}
