//! Training driver (S16): Rust owns the loop; the gradient step is the AOT
//! `train_*_b8` executable (fwd+bwd+SGD fused into one HLO module by JAX
//! at build time). Parameters live as PJRT literals and are fed back each
//! step — Python never runs.
//!
//! Two workloads:
//! * `finetune` — synthetic sequence classification (trigger-token task),
//!   the stand-in for the paper's GLUE fine-tuning stage;
//! * `lm` — next-token LM on the tiny corpus, which the text-generation
//!   demo uses to get non-random weights.

use anyhow::Result;

use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32, Runtime};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub final_loss: f32,
    pub initial_loss: f32,
    pub seconds: f64,
}

impl TrainReport {
    pub fn improved(&self) -> bool {
        self.final_loss < self.initial_loss
    }
}

/// The synthetic classification task: label = 1 iff the trigger token
/// appears in the sequence. Positives carry the trigger in ~1/4 of the
/// positions so the mean-pooled representation shifts measurably from
/// step one (a single occurrence diluted by 1/seq trains far slower —
/// this is an e2e plumbing check, not a hard benchmark).
pub const TRIGGER_TOKEN: i32 = 7;

pub fn make_cls_batch(
    rng: &mut Rng,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<i32>) {
    let mut ids = Vec::with_capacity(batch * seq);
    let mut labels = Vec::with_capacity(batch);
    for b in 0..batch {
        let positive = b % 2 == 0; // balanced
        let mut row: Vec<i32> = (0..seq)
            .map(|_| {
                let mut t = rng.below(vocab) as i32;
                if t == TRIGGER_TOKEN {
                    t += 1; // keep negatives clean
                }
                t
            })
            .collect();
        if positive {
            for _ in 0..(seq / 4).max(1) {
                let pos = rng.below(seq);
                row[pos] = TRIGGER_TOKEN;
            }
        }
        ids.extend_from_slice(&row);
        labels.push(positive as i32);
    }
    let tt = vec![0i32; batch * seq];
    let mask = vec![1.0f32; batch * seq];
    (ids, tt, mask, labels)
}

/// Fine-tune the `cls` model for `steps` steps; returns the loss curve.
pub fn finetune_cls(rt: &mut Runtime, steps: usize, lr: f32, seed: u64) -> Result<TrainReport> {
    let exe = rt.load("train_cls_b8")?;
    let mut params = rt.load_params("cls")?;
    let m = &rt.manifest.models["cls"];
    let (seq, vocab) = (m.cfg("seq"), m.cfg("vocab"));
    let n_params = params.len();
    let mut rng = Rng::new(seed);

    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let (ids, tt, mask, labels) = make_cls_batch(&mut rng, 8, seq, vocab);
        let out = exe.run(
            &params,
            &[
                lit_i32(&ids, &[8, seq])?,
                lit_i32(&tt, &[8, seq])?,
                lit_f32(&mask, &[8, seq])?,
                lit_i32(&labels, &[8])?,
                lit_scalar_f32(lr),
            ],
        )?;
        debug_assert_eq!(out.len(), n_params + 1);
        let loss = to_vec_f32(&out[n_params])?[0];
        losses.push(loss);
        let mut out = out;
        let _loss_lit = out.pop();
        params = out;
    }

    Ok(TrainReport {
        initial_loss: *losses.first().unwrap_or(&f32::NAN),
        final_loss: *losses.last().unwrap_or(&f32::NAN),
        steps,
        seconds: t0.elapsed().as_secs_f64(),
        losses,
    })
}

/// Evaluate classification accuracy of current `cls` params on fresh data.
pub fn eval_cls(
    rt: &mut Runtime,
    params: &[xla::Literal],
    batches: usize,
    seed: u64,
) -> Result<f32> {
    let exe = rt.load("cls_b8")?;
    let m = &rt.manifest.models["cls"];
    let (seq, vocab, classes) = (m.cfg("seq"), m.cfg("vocab"), 2usize);
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..batches {
        let (ids, tt, mask, labels) = make_cls_batch(&mut rng, 8, seq, vocab);
        let out = exe.run(
            params,
            &[lit_i32(&ids, &[8, seq])?, lit_i32(&tt, &[8, seq])?, lit_f32(&mask, &[8, seq])?],
        )?;
        let logits = to_vec_f32(&out[0])?; // [8, 2]
        for b in 0..8 {
            let row = &logits[b * classes..(b + 1) * classes];
            let pred = if row[1] > row[0] { 1 } else { 0 };
            correct += (pred == labels[b]) as usize;
            total += 1;
        }
    }
    Ok(correct as f32 / total as f32)
}

/// Fine-tune the causal LM on corpus windows; returns params for the
/// text-generation engine plus the loss curve.
pub fn train_lm(
    rt: &mut Runtime,
    corpus_ids: &[i32],
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(Vec<xla::Literal>, TrainReport)> {
    let exe = rt.load("train_lm_b8")?;
    let mut params = rt.load_params("gen")?;
    let m = &rt.manifest.models["gen"];
    let seq = m.cfg("seq");
    let n_params = params.len();
    anyhow::ensure!(
        corpus_ids.len() > seq + 1,
        "corpus too small: {} tokens for seq {seq}",
        corpus_ids.len()
    );
    let mut rng = Rng::new(seed);

    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut ids = Vec::with_capacity(8 * seq);
        for _ in 0..8 {
            let start = rng.below(corpus_ids.len() - seq);
            ids.extend_from_slice(&corpus_ids[start..start + seq]);
        }
        let mask = vec![1.0f32; 8 * seq];
        let out = exe.run(
            &params,
            &[lit_i32(&ids, &[8, seq])?, lit_f32(&mask, &[8, seq])?, lit_scalar_f32(lr)],
        )?;
        let loss = to_vec_f32(&out[n_params])?[0];
        losses.push(loss);
        let mut out = out;
        out.pop();
        params = out;
    }

    let report = TrainReport {
        initial_loss: *losses.first().unwrap_or(&f32::NAN),
        final_loss: *losses.last().unwrap_or(&f32::NAN),
        steps,
        seconds: t0.elapsed().as_secs_f64(),
        losses,
    };
    Ok((params, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cls_batch_is_balanced_and_clean() {
        let mut rng = Rng::new(3);
        let (ids, tt, mask, labels) = make_cls_batch(&mut rng, 8, 16, 64);
        assert_eq!(ids.len(), 8 * 16);
        assert_eq!(labels.iter().filter(|&&l| l == 1).count(), 4);
        assert!(tt.iter().all(|&t| t == 0));
        assert!(mask.iter().all(|&m| m == 1.0));
        for b in 0..8 {
            let row = &ids[b * 16..(b + 1) * 16];
            let has_trigger = row.contains(&TRIGGER_TOKEN);
            assert_eq!(has_trigger, labels[b] == 1, "row {b}");
        }
    }

    #[test]
    fn batches_vary_across_steps() {
        let mut rng = Rng::new(4);
        let (a, ..) = make_cls_batch(&mut rng, 8, 16, 64);
        let (b, ..) = make_cls_batch(&mut rng, 8, 16, 64);
        assert_ne!(a, b);
    }
}
