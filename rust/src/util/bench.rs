//! Micro-benchmark harness (substrate — criterion is unavailable offline).
//!
//! Mimics criterion's workflow: warm-up, calibrated iteration count, robust
//! statistics (median + MAD), and a stable one-line report. Used by every
//! target under `rust/benches/`.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Median absolute deviation — robust spread estimate.
    pub mad: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} median {:>12} mean  (±{:>10}, {} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.mad),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark `f`, auto-calibrating the iteration count to fill
/// `target_time`. Returns robust statistics over per-iteration samples.
pub fn bench<F: FnMut()>(name: &str, target_time: Duration, mut f: F) -> BenchStats {
    // Warm-up: run until ~10% of the target time is spent, at least once.
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_start.elapsed() < target_time / 10 || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = warm_start.elapsed() / warm_iters.max(1) as u32;

    // Choose a sample count: aim for >= 10 samples, each sample 1+ calls.
    let est_total = per_iter.max(Duration::from_nanos(1));
    let samples = ((target_time.as_nanos() / est_total.as_nanos().max(1)) as usize)
        .clamp(10, 10_000);

    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }

    times.sort();
    let median = times[times.len() / 2];
    let min = times[0];
    let max = *times.last().unwrap();
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let mut devs: Vec<i128> = times
        .iter()
        .map(|t| (t.as_nanos() as i128 - median.as_nanos() as i128).abs())
        .collect();
    devs.sort();
    let mad = Duration::from_nanos(devs[devs.len() / 2] as u64);

    BenchStats {
        name: name.to_string(),
        iters: samples,
        mean,
        median,
        min,
        max,
        mad,
    }
}

/// A bench "group" that prints a header and collects rows; mirrors
/// criterion's group output enough for `cargo bench | tee` logs.
pub struct Group {
    pub title: String,
    pub rows: Vec<BenchStats>,
    target: Duration,
}

impl Group {
    pub fn new(title: &str) -> Self {
        println!("\n== {title} ==");
        Group {
            title: title.to_string(),
            rows: Vec::new(),
            target: Duration::from_millis(300),
        }
    }

    pub fn with_target(title: &str, target: Duration) -> Self {
        let mut g = Self::new(title);
        g.target = target;
        g
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchStats {
        let stats = bench(name, self.target, f);
        println!("  {}", stats.report());
        self.rows.push(stats);
        self.rows.last().unwrap()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop-ish", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 10);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
