//! Lightweight property-based testing helper (substrate — proptest is
//! unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen`; on failure it re-raises with the failing seed and a
//! debug dump of the input so the case is reproducible. Shrinking is
//! intentionally omitted — generators here produce small inputs already.

use super::rng::Rng;

/// Run `prop` over `cases` generated inputs. Panics with the failing input
/// (and the per-case seed) on the first violation.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case} (seed {case_seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Convenience: assert two f32 slices are close.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("mismatch at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(1, 50, |r| r.below(100), |&n| {
            if n < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 50, |r| r.below(10), |&n| {
            if n < 5 {
                Ok(())
            } else {
                Err(format!("{n} too big"))
            }
        });
    }

    #[test]
    fn close_check() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.000001], 1e-5, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, 1e-6).is_err());
    }
}
