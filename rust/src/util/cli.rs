//! Tiny CLI argument parser (substrate — clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and generated usage text. Used by `main.rs` and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub bools: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` minus the program name. `bool_flags` lists
    /// flags that take no value.
    pub fn parse(raw: impl Iterator<Item = String>, bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&rest) {
                    out.bools.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.bools.push(rest.to_string());
                    } else {
                        out.flags.insert(rest.to_string(), it.next().unwrap());
                    }
                } else {
                    out.bools.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(bool_flags: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.bools.iter().any(|b| b == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], bools: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), bools)
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--x", "1", "--y=2", "pos"], &[]);
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.get("y"), Some("2"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["--verbose", "--n", "3"], &["verbose"]);
        assert!(a.has("verbose"));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn trailing_flag_is_bool() {
        let a = parse(&["--x", "1", "--flag"], &[]);
        assert!(a.has("flag"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
    }
}
