//! Minimal JSON parser/serializer (substrate — no serde offline).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough for
//! `artifacts/manifest.json` and config files. Zero dependencies.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name (for manifests).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation — one key per line, so files
    /// committed for trend tracking (e.g. `BENCH_serving.json`) produce
    /// readable per-metric diffs.
    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(k, out);
                    out.push_str(": ");
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: combine if a high surrogate.
                            let cp = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_unicode() {
        let j = Json::parse(r#""é😀é""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀é"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"x"],"n":-3,"o":{"t":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let src = r#"{"a":[1,2],"b":{"c":"x"},"empty":[],"n":null}"#;
        let j = Json::parse(src).unwrap();
        let pretty = j.dump_pretty();
        assert_eq!(Json::parse(pretty.trim()).unwrap(), j, "pretty form parses back");
        assert!(pretty.contains("\n  \"a\": [\n"), "{pretty}");
        assert!(pretty.contains("\"empty\": []"), "empty containers stay inline");
        assert!(pretty.ends_with('\n'), "file-friendly trailing newline");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }
}
