//! In-tree substrates replacing crates that are unavailable offline:
//! JSON (serde_json), RNG (rand), bench harness (criterion), CLI (clap),
//! property testing (proptest), thread pool (tokio), metrics.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
