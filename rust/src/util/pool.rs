//! Worker/buffer substrate for the parallel executor and the serving
//! layer (tokio/rayon are unavailable offline):
//!
//! * [`Pool`] — a minimal thread pool: boxed-closure jobs with
//!   `wait_idle` joining. Used for bounded worker concurrency.
//! * [`Slab`] / [`SharedSlab`] — one flat f32 allocation that backs the
//!   arena-planned executor buffers. The arena planner
//!   (`compiler::exec::arena`) assigns every materialized tensor an
//!   `(offset, len)` region; `SharedSlab` hands out disjoint `&[f32]` /
//!   `&mut [f32]` regions across the wave executor's scoped threads.
//!   Safety is the planner's no-overlap guarantee — see the `unsafe`
//!   accessor contracts below.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Flat f32 storage for offset-assigned tensor regions.
pub struct Slab {
    data: Vec<f32>,
}

impl Slab {
    pub fn new(len: usize) -> Slab {
        Slab { data: vec![0.0f32; len] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Grow (never shrink) the backing storage to at least `len` elements.
    /// New elements are zeroed; executors never read a region before
    /// writing it, so recycled contents are harmless either way.
    pub fn ensure_len(&mut self, len: usize) {
        if self.data.len() < len {
            self.data.resize(len, 0.0);
        }
    }

    /// Safe shared view of the whole storage — for owners that manage
    /// their own region layout with ordinary borrows (e.g. the decode
    /// subsystem's KV cache), as opposed to the wave executor's
    /// cross-thread [`SharedSlab`] accessors.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Safe exclusive view of the whole storage (see [`Slab::data`]).
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Borrow the whole slab as a shareable handle. The `&mut` receiver
    /// guarantees no other safe borrow of the storage exists while
    /// `SharedSlab` copies are alive.
    pub fn shared(&mut self) -> SharedSlab<'_> {
        SharedSlab {
            ptr: self.data.as_mut_ptr(),
            len: self.data.len(),
            _marker: PhantomData,
        }
    }
}

/// Recycling pool of [`Slab`]s for steady-state serving: the wave
/// executor checks a slab out per execution and returns it afterwards, so
/// after warm-up no request performs a large allocation. The pool grows
/// to the peak number of *concurrent* executions and no further; a
/// checked-out slab that is too small (e.g. the pool was cloned across
/// models) is grown in place.
pub struct SlabPool {
    slabs: Mutex<Vec<Slab>>,
}

impl SlabPool {
    pub fn new() -> SlabPool {
        SlabPool { slabs: Mutex::new(Vec::new()) }
    }

    /// Take a slab with at least `len` elements, reusing a parked one
    /// when available.
    pub fn checkout(&self, len: usize) -> Slab {
        let recycled = self.slabs.lock().unwrap().pop();
        match recycled {
            Some(mut s) => {
                s.ensure_len(len);
                s
            }
            None => Slab::new(len),
        }
    }

    /// Park a slab for reuse by a later execution.
    pub fn give_back(&self, slab: Slab) {
        self.slabs.lock().unwrap().push(slab);
    }

    /// Number of slabs currently parked.
    pub fn len(&self) -> usize {
        self.slabs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for SlabPool {
    fn default() -> Self {
        SlabPool::new()
    }
}

/// Pools are warm caches: a cloned `SlabPool` (e.g. cloning a cached
/// `PreparedExec`) starts cold rather than duplicating buffers.
impl Clone for SlabPool {
    fn clone(&self) -> Self {
        SlabPool::new()
    }
}

impl std::fmt::Debug for SlabPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SlabPool({} parked)", self.len())
    }
}

/// Copyable handle to a `Slab` that can be sent across scoped threads.
/// All region accessors are `unsafe`: the caller (the wave executor)
/// must guarantee that, at any instant, a region handed out with
/// [`SharedSlab::write`] overlaps neither another live `write` region nor
/// any live [`SharedSlab::read`] region. The arena planner provides
/// exactly that guarantee: values live in the same wave never share
/// offsets, and a region is only reused after its last reader's wave has
/// completed.
#[derive(Clone, Copy)]
pub struct SharedSlab<'a> {
    ptr: *mut f32,
    len: usize,
    _marker: PhantomData<&'a mut [f32]>,
}

// SAFETY: the raw pointer is only dereferenced through the region
// accessors, whose contracts forbid concurrent aliasing writes.
unsafe impl Send for SharedSlab<'_> {}
unsafe impl Sync for SharedSlab<'_> {}

impl<'a> SharedSlab<'a> {
    pub fn len(self) -> usize {
        self.len
    }

    pub fn is_empty(self) -> bool {
        self.len == 0
    }

    /// Read a region. SAFETY: no thread may concurrently `write` an
    /// overlapping region.
    pub unsafe fn read(self, offset: usize, len: usize) -> &'a [f32] {
        assert!(offset + len <= self.len, "slab read out of bounds");
        std::slice::from_raw_parts(self.ptr.add(offset), len)
    }

    /// Write a region. SAFETY: the region must be exclusive — no
    /// concurrent `read` or `write` may overlap it.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn write(self, offset: usize, len: usize) -> &'a mut [f32] {
        assert!(offset + len <= self.len, "slab write out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(offset), len)
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct Pool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    submitted: AtomicUsize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        assert!(threads > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Msg>>> = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("canao-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                if *n == 0 {
                                    cv.notify_all();
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { tx, workers, pending, submitted: AtomicUsize::new(0) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn jobs_submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.jobs_submitted(), 100);
    }

    #[test]
    fn slab_disjoint_regions_across_threads() {
        let mut slab = Slab::new(64);
        let shared = slab.shared();
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    // SAFETY: regions [16t, 16t+16) are pairwise disjoint.
                    let region = unsafe { shared.write(t * 16, 16) };
                    for (i, v) in region.iter_mut().enumerate() {
                        *v = (t * 16 + i) as f32;
                    }
                });
            }
        });
        // SAFETY: all writers joined.
        let all = unsafe { shared.read(0, 64) };
        for (i, &v) in all.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn slab_pool_recycles_and_grows() {
        let pool = SlabPool::new();
        assert!(pool.is_empty());
        let a = pool.checkout(16);
        assert_eq!(a.len(), 16);
        pool.give_back(a);
        assert_eq!(pool.len(), 1);
        // Reuse grows in place when a larger slab is needed...
        let b = pool.checkout(64);
        assert!(pool.is_empty(), "the parked slab was reused, not left behind");
        assert_eq!(b.len(), 64);
        pool.give_back(b);
        // ...and a smaller request reuses the bigger slab as-is.
        let c = pool.checkout(8);
        assert_eq!(c.len(), 64);
        pool.give_back(c);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slab_bounds_checked() {
        let mut slab = Slab::new(8);
        let shared = slab.shared();
        // SAFETY: sole accessor; the call must panic on bounds.
        let _ = unsafe { shared.read(4, 8) };
    }

    #[test]
    fn parallel_speedup_observable() {
        // Not a perf assertion — just that work really runs on >1 thread.
        let pool = Pool::new(4);
        let ids = Arc::new(Mutex::new(std::collections::HashSet::new()));
        for _ in 0..32 {
            let ids = Arc::clone(&ids);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                ids.lock().unwrap().insert(std::thread::current().id());
            });
        }
        pool.wait_idle();
        assert!(ids.lock().unwrap().len() > 1);
    }
}
