//! Minimal thread pool (substrate — tokio is unavailable offline, and the
//! serving path only needs bounded worker concurrency, not async I/O).
//!
//! Jobs are boxed closures; `Pool::scope`-style joining is provided via
//! `wait_idle`. The serving engine uses one pool for tokenization and one
//! worker thread per PJRT executable (PJRT execution is internally
//! multi-threaded already).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct Pool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    submitted: AtomicUsize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        assert!(threads > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Msg>>> = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::Builder::new()
                    .name(format!("canao-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                if *n == 0 {
                                    cv.notify_all();
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { tx, workers, pending, submitted: AtomicUsize::new(0) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn jobs_submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.jobs_submitted(), 100);
    }

    #[test]
    fn parallel_speedup_observable() {
        // Not a perf assertion — just that work really runs on >1 thread.
        let pool = Pool::new(4);
        let ids = Arc::new(Mutex::new(std::collections::HashSet::new()));
        for _ in 0..32 {
            let ids = Arc::clone(&ids);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                ids.lock().unwrap().insert(std::thread::current().id());
            });
        }
        pool.wait_idle();
        assert!(ids.lock().unwrap().len() > 1);
    }
}
