//! Deterministic RNG (substrate — the `rand` crate is unavailable offline).
//!
//! SplitMix64 core with helpers used across the repo: uniform floats,
//! ranges, normal sampling (Box–Muller), categorical sampling from logits,
//! and shuffling. Everything is seed-reproducible, which the NAS search,
//! the synthetic data generators, and the property tests all rely on.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal from Box–Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample an index from unnormalized logits with a temperature.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        assert!(!logits.is_empty());
        if temperature <= 1e-6 {
            // Greedy.
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
        }
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - max) / temperature) as f64).exp())
            .collect();
        let total: f64 = exps.iter().sum();
        let mut t = self.f64() * total;
        for (i, e) in exps.iter().enumerate() {
            t -= e;
            if t <= 0.0 {
                return i;
            }
        }
        exps.len() - 1
    }

    /// Sample from explicit probabilities (must sum to ~1).
    pub fn sample_probs(&mut self, probs: &[f32]) -> usize {
        let mut t = self.f64() as f32;
        for (i, p) in probs.iter().enumerate() {
            t -= p;
            if t <= 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn sample_logits_greedy() {
        let mut r = Rng::new(3);
        assert_eq!(r.sample_logits(&[0.0, 5.0, 1.0], 0.0), 1);
    }

    #[test]
    fn sample_logits_distribution() {
        let mut r = Rng::new(4);
        let logits = [0.0f32, 2.0, 0.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.sample_logits(&logits, 1.0)] += 1;
        }
        // P(1) = e^2 / (e^2 + 2) ≈ 0.787
        assert!(counts[1] > 3500, "{counts:?}");
        assert!(counts[0] > 200 && counts[2] > 200, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
