//! Latency/throughput metrics for the serving path and benches.

use std::time::Duration;

/// Online reservoir of latency samples with percentile queries.
#[derive(Debug, Default, Clone)]
pub struct LatencyHistogram {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
    }

    /// p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> Duration {
        assert!(!self.samples_us.is_empty(), "empty histogram");
        self.ensure_sorted();
        let rank = ((p / 100.0) * (self.samples_us.len() - 1) as f64).round() as usize;
        Duration::from_micros(self.samples_us[rank.min(self.samples_us.len() - 1)])
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.samples_us.iter().sum::<u64>() / self.samples_us.len() as u64,
        )
    }

    pub fn summary(&mut self) -> String {
        if self.is_empty() {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:?} p50={:?} p90={:?} p99={:?}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
        )
    }
}

/// Simple mean/std accumulator (Welford).
#[derive(Debug, Default, Clone, Copy)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        assert!(h.percentile(50.0) <= h.percentile(90.0));
        assert!(h.percentile(90.0) <= h.percentile(99.0));
        assert_eq!(h.percentile(100.0), Duration::from_micros(100));
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.var() - var).abs() < 1e-12);
    }
}
