//! Latency/throughput statistics for benches and tests.
//!
//! Everything here is **exact-sample mode**: every observation is kept,
//! percentiles are computed from the sorted samples. That is the right
//! tool for bounded runs (benches, tests asserting exact counts) and the
//! wrong tool for a long-running server — memory grows per request. The
//! serving hot path uses `serving::metrics::StreamingHistogram` instead
//! (fixed-size, lock-free, bucketed percentiles).

use std::time::Duration;

use crate::util::json::Json;

/// Online reservoir of latency samples with percentile queries.
#[derive(Debug, Default, Clone)]
pub struct LatencyHistogram {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
    }

    /// p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> Duration {
        assert!(!self.samples_us.is_empty(), "empty histogram");
        self.ensure_sorted();
        let rank = ((p / 100.0) * (self.samples_us.len() - 1) as f64).round() as usize;
        Duration::from_micros(self.samples_us[rank.min(self.samples_us.len() - 1)])
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.samples_us.iter().sum::<u64>() / self.samples_us.len() as u64,
        )
    }

    pub fn summary(&mut self) -> String {
        if self.is_empty() {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:?} p50={:?} p90={:?} p99={:?}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
        )
    }
}

/// Exact percentile summary over `f64` millisecond samples — the shape
/// the load-generator bench reports (and serializes into
/// `BENCH_serving.json`). Construction consumes the samples; an empty
/// sample set yields `None`, so aggregation can never divide by zero
/// (the `NaN tok/s` guard).
#[derive(Debug, Clone, PartialEq)]
pub struct MsSummary {
    pub n: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl MsSummary {
    pub fn from_samples(mut xs: Vec<f64>) -> Option<MsSummary> {
        if xs.is_empty() {
            return None;
        }
        xs.sort_by(f64::total_cmp);
        let pct = |p: f64| {
            let rank = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
            xs[rank.min(xs.len() - 1)]
        };
        Some(MsSummary {
            n: xs.len(),
            mean_ms: xs.iter().sum::<f64>() / xs.len() as f64,
            p50_ms: pct(50.0),
            p95_ms: pct(95.0),
            p99_ms: pct(99.0),
            max_ms: *xs.last().expect("non-empty"),
        })
    }

    /// Round to 3 decimals so serialized reports diff stably.
    fn r3(x: f64) -> f64 {
        (x * 1e3).round() / 1e3
    }

    pub fn json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("mean_ms".to_string(), Json::Num(Self::r3(self.mean_ms)));
        m.insert("p50_ms".to_string(), Json::Num(Self::r3(self.p50_ms)));
        m.insert("p95_ms".to_string(), Json::Num(Self::r3(self.p95_ms)));
        m.insert("p99_ms".to_string(), Json::Num(Self::r3(self.p99_ms)));
        m.insert("max_ms".to_string(), Json::Num(Self::r3(self.max_ms)));
        Json::Obj(m)
    }
}

/// Simple mean/std accumulator (Welford).
#[derive(Debug, Default, Clone, Copy)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        assert!(h.percentile(50.0) <= h.percentile(90.0));
        assert!(h.percentile(90.0) <= h.percentile(99.0));
        assert_eq!(h.percentile(100.0), Duration::from_micros(100));
    }

    #[test]
    fn ms_summary_exact_and_empty_guard() {
        assert_eq!(MsSummary::from_samples(Vec::new()), None, "empty never divides");
        let s = MsSummary::from_samples((1..=100).map(|i| i as f64).collect()).unwrap();
        assert_eq!(s.n, 100);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert_eq!(s.p50_ms, 51.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.max_ms, 100.0);
        let j = s.json();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(100));
        assert_eq!(j.get("p95_ms").unwrap().as_f64(), Some(95.0));
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.var() - var).abs() < 1e-12);
    }
}
