//! Batcher fault injection: every server-side failure a caller can hit
//! must surface as a typed `BatcherError` — never a hang (the old
//! short-batch behavior: `debug_assert` + block forever on `recv`) and
//! never a propagated panic (the old `.expect` on the reply channel).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use canao::serving::batcher::{BatchModel, Batcher, BatcherError, BatcherOptions};

fn opts(max_wait_ms: u64, min_batch: usize, queue_cap: usize) -> BatcherOptions {
    BatcherOptions {
        max_wait: Duration::from_millis(max_wait_ms),
        min_batch,
        queue_cap,
    }
}

/// Returns one response fewer than requested whenever the batch has more
/// than one item (a buggy model dropping the tail).
struct ShortChanger;

impl BatchModel<u32, u32> for ShortChanger {
    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, items: &[u32]) -> Vec<u32> {
        let keep = if items.len() > 1 { items.len() - 1 } else { 1 };
        items.iter().take(keep).map(|x| x + 1).collect()
    }
}

#[test]
fn short_batch_fails_the_tail_instead_of_hanging() {
    // Generous max_wait: the worker must gather all 4 submits into one
    // batch even under rough CI scheduling, so a multi-item (short)
    // batch is guaranteed.
    let b = Arc::new(Batcher::new(ShortChanger, opts(500, 4, 64)));
    // Submit a burst so a multi-item batch forms; the last job in that
    // batch must get a ShortBatch error, not block forever.
    let rxs: Vec<_> = (0..4u32).map(|i| b.submit(i).expect("queue has room")).collect();
    let mut ok = 0;
    let mut short = 0;
    for rx in rxs {
        // recv() returning at all is the point of the fix; a timeout here
        // means a caller would have hung in production.
        match rx.recv_timeout(Duration::from_secs(10)).expect("no caller hangs") {
            Ok(_) => ok += 1,
            Err(BatcherError::ShortBatch { expected, got }) => {
                assert!(got < expected, "short means short: {got} < {expected}");
                short += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(ok + short, 4, "every submitted request got a reply");
    assert!(short >= 1, "at least one tail job failed typed");
    assert_eq!(b.metrics.failed.get(), short as u64);
    // The worker survives a short batch: new singleton requests still work.
    assert_eq!(b.call(10), Ok(11));
}

/// Panics on any batch containing the poison value.
struct Panicker;

impl BatchModel<u32, u32> for Panicker {
    fn max_batch(&self) -> usize {
        4
    }

    fn run_batch(&self, items: &[u32]) -> Vec<u32> {
        if items.contains(&666) {
            panic!("injected model fault");
        }
        items.iter().map(|x| x * 2).collect()
    }
}

#[test]
fn model_panic_fails_batch_without_panicking_callers() {
    let b = Batcher::new(Panicker, opts(2, 1, 64));
    // Healthy request first: the model works until poisoned.
    assert_eq!(b.call(3), Ok(6));

    // The poisoned request must come back as a typed error — the old
    // implementation panicked the *caller* here (expect on a dead
    // channel) after the worker died.
    assert_eq!(b.call(666), Err(BatcherError::ModelPanicked));

    // The worker is gone and says so; no panic, no hang.
    match b.submit(1) {
        Err(BatcherError::WorkerGone) => {}
        other => panic!("expected WorkerGone, got {other:?}"),
    }
    assert_eq!(b.call(2), Err(BatcherError::WorkerGone));
    assert_eq!(b.metrics.failed.get(), 1, "the poisoned job failed typed");
    // Dropping a batcher whose worker already exited must not hang/panic.
    drop(b);
}

#[test]
fn jobs_queued_behind_a_panic_fail_typed() {
    // Slow down batch formation so we can pile jobs up behind the poison
    // pill: min_batch 1 + max_wait 0 makes the worker run singletons,
    // and the sleep in submit order keeps the queue populated.
    struct SlowPanicker;
    impl BatchModel<u32, u32> for SlowPanicker {
        fn max_batch(&self) -> usize {
            1
        }
        fn run_batch(&self, items: &[u32]) -> Vec<u32> {
            std::thread::sleep(Duration::from_millis(20));
            if items.contains(&666) {
                panic!("injected model fault");
            }
            items.to_vec()
        }
    }

    let b = Batcher::new(SlowPanicker, opts(0, 1, 64));
    let poison = b.submit(666).expect("queue has room");
    // These queue up behind the poison pill (the worker sleeps 20ms
    // inside the poison batch while they arrive). If scheduling is so
    // skewed that the worker already died, submit itself returns the
    // typed WorkerGone — also a pass.
    let behind: Vec<_> = (0..5u32).map(|i| b.submit(i)).collect();

    assert_eq!(
        poison.recv_timeout(Duration::from_secs(10)).expect("typed, not a hang"),
        Err(BatcherError::ModelPanicked)
    );
    for sub in behind {
        match sub {
            Err(BatcherError::WorkerGone) => {} // refused at the door: typed
            Err(e) => panic!("unexpected submit error: {e}"),
            // Admitted, then drained at worker death (WorkerGone). A
            // reply sender dropped during teardown also unblocks the
            // caller as an error — never a hang.
            Ok(rx) => match rx.recv_timeout(Duration::from_secs(10)) {
                Ok(Err(BatcherError::WorkerGone)) => {}
                Ok(other) => panic!("expected typed failure, got {other:?}"),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    panic!("caller hung behind a dead worker")
                }
                Err(_) => {} // disconnected: caller unblocks with an error
            },
        }
    }
}

/// Counts how many requests actually reach the model.
struct CountingSleeper(Arc<AtomicUsize>);

impl BatchModel<u32, u32> for CountingSleeper {
    fn max_batch(&self) -> usize {
        2
    }

    fn run_batch(&self, items: &[u32]) -> Vec<u32> {
        std::thread::sleep(Duration::from_millis(10));
        self.0.fetch_add(items.len(), Ordering::Relaxed);
        items.to_vec()
    }
}

#[test]
fn full_queue_rejects_typed_and_admitted_jobs_complete() {
    let ran = Arc::new(AtomicUsize::new(0));
    let b = Batcher::new(CountingSleeper(Arc::clone(&ran)), opts(1, 1, 4));

    // Burst far past capacity. The worker can drain at most a few while
    // we submit, so rejections are guaranteed.
    let mut admitted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..64u32 {
        match b.submit(i) {
            Ok(rx) => admitted.push(rx),
            Err(BatcherError::QueueFull { capacity }) => {
                assert_eq!(capacity, 4);
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected > 0, "burst of 64 into cap-4 queue must reject");
    assert_eq!(b.metrics.rejected.get(), rejected);

    // Every admitted job completes; every rejected one never ran.
    for rx in &admitted {
        assert!(rx.recv_timeout(Duration::from_secs(10)).expect("no hang").is_ok());
    }
    let admitted_n = admitted.len();
    drop(admitted);
    b.shutdown();
    assert_eq!(ran.load(Ordering::Relaxed), admitted_n, "rejected jobs never ran");
    assert_eq!(admitted_n as u64 + rejected, 64);
}

#[test]
fn receiver_dropped_mid_flight_does_not_wedge_the_worker() {
    let b = Batcher::new(Panicker, opts(1, 1, 16));
    // Submit and immediately drop the receiver while the job is in
    // flight; the worker's reply send fails silently and it moves on.
    for i in 0..8u32 {
        drop(b.submit(i).expect("queue has room"));
    }
    // Worker still alive and serving.
    assert_eq!(b.call(5), Ok(10));
}
