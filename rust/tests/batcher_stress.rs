//! Batcher concurrency stress: many producer threads submitting through
//! one `Batcher` concurrently. Asserts no response is lost, duplicated,
//! or cross-wired; that `BatcherMetrics` counts add up exactly; and that
//! shutdown joins cleanly with the queue drained (the test would hang or
//! panic otherwise).

use std::sync::Arc;
use std::time::Duration;

use canao::serving::batcher::{BatchModel, Batcher, BatcherOptions};

/// Tags each request with the batch it ran in; the payload echo proves
/// responses reach the submitter that asked.
struct TaggingEcho;

impl BatchModel<(u32, u32), (u32, u32, usize)> for TaggingEcho {
    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, items: &[(u32, u32)]) -> Vec<(u32, u32, usize)> {
        // A little jitter so batches of every size form under load.
        std::thread::sleep(Duration::from_micros(200));
        items.iter().map(|&(p, s)| (p, s, items.len())).collect()
    }
}

#[test]
fn producers_never_lose_or_cross_responses() {
    const PRODUCERS: u32 = 8;
    const PER_PRODUCER: u32 = 50;

    let batcher = Arc::new(Batcher::new(
        TaggingEcho,
        BatcherOptions { max_wait: Duration::from_millis(2), min_batch: 4 },
    ));
    let metrics = Arc::clone(&batcher.metrics);

    std::thread::scope(|scope| {
        for p in 0..PRODUCERS {
            let batcher = Arc::clone(&batcher);
            scope.spawn(move || {
                // Submit a burst, then await all replies — forces real
                // cross-producer interleaving in the queue.
                let rxs: Vec<_> =
                    (0..PER_PRODUCER).map(|s| (s, batcher.submit((p, s)))).collect();
                for (s, rx) in rxs {
                    let (rp, rs, batch_len) = rx.recv().expect("reply must arrive");
                    assert_eq!((rp, rs), (p, s), "response cross-wired");
                    assert!(batch_len >= 1 && batch_len <= 8);
                }
            });
        }
    });

    // Clean shutdown: worker drained and joined (hangs the test if not).
    match Arc::try_unwrap(batcher) {
        Ok(b) => b.shutdown(),
        Err(_) => panic!("all producers done; batcher must be uniquely owned"),
    }

    let total = (PRODUCERS * PER_PRODUCER) as usize;
    let m = metrics.lock().unwrap();
    assert_eq!(m.requests, total, "every submitted request counted");
    assert_eq!(m.responses, total, "every reply delivered exactly once");
    assert_eq!(
        m.batch_sizes.iter().sum::<usize>(),
        total,
        "batch sizes partition the requests"
    );
    assert_eq!(m.batch_sizes.len(), m.batches);
    assert!(m.batches <= total, "batching never inflates batch count");
    assert!(
        m.batch_sizes.iter().all(|&s| (1..=8).contains(&s)),
        "batch size bounds: {:?}",
        &m.batch_sizes[..m.batch_sizes.len().min(16)]
    );
    assert!(m.mean_batch_size() >= 1.0);
    assert_eq!(m.queue_latency.len(), total);
    assert_eq!(m.total_latency.len(), total);
}

/// Dropping receivers must not wedge the worker or corrupt counts.
#[test]
fn abandoned_receivers_are_tolerated() {
    let batcher = Batcher::new(
        TaggingEcho,
        BatcherOptions { max_wait: Duration::from_millis(1), min_batch: 2 },
    );
    let metrics = Arc::clone(&batcher.metrics);

    // Half the callers give up immediately.
    let mut kept = Vec::new();
    for s in 0..20u32 {
        let rx = batcher.submit((0, s));
        if s % 2 == 0 {
            kept.push((s, rx));
        } // odd receivers dropped here
    }
    for (s, rx) in kept {
        let (_, rs, _) = rx.recv().unwrap();
        assert_eq!(rs, s);
    }
    batcher.shutdown();

    let m = metrics.lock().unwrap();
    assert_eq!(m.requests, 20);
    assert!(m.responses >= 10, "kept receivers all answered: {}", m.responses);
    assert!(m.responses <= 20);
}
