//! Batcher concurrency stress: many producer threads submitting through
//! one `Batcher` concurrently. Asserts no response is lost, duplicated,
//! or cross-wired; that `BatcherMetrics` counts add up exactly; and that
//! shutdown joins cleanly with the queue drained (the test would hang or
//! panic otherwise).

use std::sync::Arc;
use std::time::Duration;

use canao::serving::batcher::{BatchModel, Batcher, BatcherOptions};

/// Tags each request with the batch it ran in; the payload echo proves
/// responses reach the submitter that asked.
struct TaggingEcho;

impl BatchModel<(u32, u32), (u32, u32, usize)> for TaggingEcho {
    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, items: &[(u32, u32)]) -> Vec<(u32, u32, usize)> {
        // A little jitter so batches of every size form under load.
        std::thread::sleep(Duration::from_micros(200));
        items.iter().map(|&(p, s)| (p, s, items.len())).collect()
    }
}

#[test]
fn producers_never_lose_or_cross_responses() {
    const PRODUCERS: u32 = 8;
    const PER_PRODUCER: u32 = 50;

    // queue_cap must cover the full outstanding burst (8 producers x 50
    // requests); this test asserts exact accounting, so no admission
    // rejects are allowed (rejection under burst is tested separately in
    // tests/batcher_faults.rs).
    let batcher = Arc::new(Batcher::new(
        TaggingEcho,
        BatcherOptions {
            max_wait: Duration::from_millis(2),
            min_batch: 4,
            queue_cap: (PRODUCERS * PER_PRODUCER) as usize,
        },
    ));
    let metrics = Arc::clone(&batcher.metrics);

    std::thread::scope(|scope| {
        for p in 0..PRODUCERS {
            let batcher = Arc::clone(&batcher);
            scope.spawn(move || {
                // Submit a burst, then await all replies — forces real
                // cross-producer interleaving in the queue.
                let rxs: Vec<_> = (0..PER_PRODUCER)
                    .map(|s| (s, batcher.submit((p, s)).expect("queue has room")))
                    .collect();
                for (s, rx) in rxs {
                    let (rp, rs, batch_len) =
                        rx.recv().expect("reply must arrive").expect("model never fails");
                    assert_eq!((rp, rs), (p, s), "response cross-wired");
                    assert!(batch_len >= 1 && batch_len <= 8);
                }
            });
        }
    });

    // Clean shutdown: worker drained and joined (hangs the test if not).
    match Arc::try_unwrap(batcher) {
        Ok(b) => b.shutdown(),
        Err(_) => panic!("all producers done; batcher must be uniquely owned"),
    }

    let total = (PRODUCERS * PER_PRODUCER) as u64;
    assert_eq!(metrics.requests.get(), total, "every submitted request counted");
    assert_eq!(metrics.responses.get(), total, "every reply delivered exactly once");
    assert_eq!(metrics.rejected.get(), 0, "queue sized for the burst");
    assert_eq!(metrics.failed.get(), 0);
    assert_eq!(
        metrics.batch_occupancy.sum(),
        total,
        "batch sizes partition the requests"
    );
    assert_eq!(metrics.batch_occupancy.len(), metrics.batches.get());
    assert!(metrics.batches.get() <= total, "batching never inflates batch count");
    assert!(
        metrics.batch_occupancy.max_value() <= 8,
        "batch size bound: max {}",
        metrics.batch_occupancy.max_value()
    );
    assert!(metrics.mean_batch_size() >= 1.0);
    assert_eq!(metrics.queue_latency.len(), total);
    assert_eq!(metrics.total_latency.len(), total);
    assert_eq!(metrics.queue_depth.get(), 0, "queue fully drained");
    assert!(metrics.queue_depth.peak() >= 1, "burst actually queued");
}

/// Dropping receivers must not wedge the worker or corrupt counts.
#[test]
fn abandoned_receivers_are_tolerated() {
    let batcher = Batcher::new(
        TaggingEcho,
        BatcherOptions {
            max_wait: Duration::from_millis(1),
            min_batch: 2,
            queue_cap: 64,
        },
    );
    let metrics = Arc::clone(&batcher.metrics);

    // Half the callers give up immediately.
    let mut kept = Vec::new();
    for s in 0..20u32 {
        let rx = batcher.submit((0, s)).expect("queue has room");
        if s % 2 == 0 {
            kept.push((s, rx));
        } // odd receivers dropped here
    }
    for (s, rx) in kept {
        let (_, rs, _) = rx.recv().unwrap().unwrap();
        assert_eq!(rs, s);
    }
    batcher.shutdown();

    assert_eq!(metrics.requests.get(), 20);
    assert!(
        metrics.responses.get() >= 10,
        "kept receivers all answered: {}",
        metrics.responses.get()
    );
    assert!(metrics.responses.get() <= 20);
}
