//! Integration tests over the full compiler pipeline: passes -> LP-Fusion
//! -> codegen -> plan execution, on realistic transformer graphs, checked
//! against the reference interpreter.

use std::collections::HashMap;

use canao::compiler::exec::interp::eval_graph;
use canao::compiler::fusion::{lp_fusion, BlockKind, FusionConfig};
use canao::compiler::ir::{DType, Graph, Op};
use canao::compiler::poly::fusion_legal;
use canao::compiler::{compile, CompileOptions};
use canao::model::{build_encoder, BertConfig};
use canao::util::check::assert_close;
use canao::util::rng::Rng;

fn feeds_for(g: &Graph, seed: u64) -> HashMap<String, Vec<f32>> {
    let mut rng = Rng::new(seed);
    let mut feeds = HashMap::new();
    for node in &g.nodes {
        match &node.op {
            Op::Input { name } => {
                let data: Vec<f32> = if node.dtype == DType::I32 {
                    (0..node.shape.numel()).map(|_| rng.below(32) as f32).collect()
                } else if name.starts_with("mask") {
                    vec![0.0; node.shape.numel()] // additive mask: attend all
                } else {
                    (0..node.shape.numel()).map(|_| rng.normal_f32(0.0, 1.0)).collect()
                };
                feeds.insert(name.clone(), data);
            }
            Op::Weight { name } => {
                let data: Vec<f32> = if name.ends_with("gamma") {
                    vec![1.0; node.shape.numel()]
                } else if name.ends_with("beta") || name.contains("/b") {
                    vec![0.0; node.shape.numel()]
                } else {
                    (0..node.shape.numel()).map(|_| rng.normal_f32(0.0, 0.05)).collect()
                };
                feeds.insert(name.clone(), data);
            }
            _ => {}
        }
    }
    feeds
}

/// A one-layer transformer encoder, compiled with fusion, must produce the
/// same numbers as the unfused reference interpreter on the ORIGINAL graph
/// — semantics preservation across the entire pipeline.
#[test]
fn tiny_bert_fused_execution_matches_interpreter() {
    let cfg = BertConfig { vocab: 64, seq: 8, layers: 1, hidden: 16, heads: 2, inter: 32 };
    let g = build_encoder(&cfg);
    let feeds = feeds_for(&g, 42);
    let expect = eval_graph(&g, &feeds).unwrap();

    for opts in [
        CompileOptions::default(),
        CompileOptions::no_fusion(),
        CompileOptions { skip_passes: true, ..Default::default() },
        CompileOptions { model_only_tuning: true, ..Default::default() },
    ] {
        let c = compile(&g, &opts);
        let got = c.run(&feeds).unwrap();
        assert_eq!(got.len(), expect.len());
        for (e, o) in expect.iter().zip(&got) {
            assert_close(&o.data, &e.data, 2e-3, 2e-3).unwrap();
        }
    }
}

#[test]
fn two_layer_bert_matches_too() {
    let cfg = BertConfig { vocab: 32, seq: 4, layers: 2, hidden: 8, heads: 2, inter: 16 };
    let g = build_encoder(&cfg);
    let feeds = feeds_for(&g, 7);
    let expect = eval_graph(&g, &feeds).unwrap();
    let c = compile(&g, &CompileOptions::default());
    let got = c.run(&feeds).unwrap();
    assert_close(&got[0].data, &expect[0].data, 2e-3, 2e-3).unwrap();
}

/// The fusion statistics the paper reports: fusing a transformer layer
/// must collapse the softmax (5 ops), each layernorm (12 ops), the GELU
/// (7 ops) and the residual adds into a handful of blocks.
#[test]
fn fusion_collapses_transformer_op_count() {
    let cfg = BertConfig { vocab: 64, seq: 16, layers: 2, hidden: 32, heads: 2, inter: 64 };
    let g = build_encoder(&cfg);
    let fused = compile(&g, &CompileOptions { model_only_tuning: true, ..Default::default() });
    let (ops, blocks, ratio) = fused.fusion_summary();
    assert!(ops > 100, "{ops}");
    assert!(ratio > 2.5, "ops/block only {ratio:.2}");
    // Per-layer block count should be ~constant.
    let cfg1 = BertConfig { layers: 1, ..cfg };
    let g1 = build_encoder(&cfg1);
    let f1 = compile(&g1, &CompileOptions { model_only_tuning: true, ..Default::default() });
    let per_layer = blocks - f1.plan.num_blocks();
    assert!(per_layer > 0 && per_layer < 40, "{per_layer}");
}

/// Every fused block in a real model graph satisfies the polyhedral
/// legality invariant.
#[test]
fn all_blocks_legal_on_bert_graph() {
    let cfg = BertConfig { vocab: 64, seq: 8, layers: 2, hidden: 16, heads: 2, inter: 32 };
    let g = build_encoder(&cfg);
    let plan = lp_fusion(&g, &FusionConfig::default());
    for b in &plan.blocks {
        assert!(fusion_legal(&g, b), "block {} illegal: {:?}", b.id, b.nodes);
    }
}

/// The attention core (matmul-softmax-matmul) must be discovered as a
/// fused block in the real model graph — the paper's key fusion.
#[test]
fn attention_core_found_in_bert_graph() {
    let cfg = BertConfig { vocab: 64, seq: 8, layers: 1, hidden: 16, heads: 2, inter: 32 };
    let g = build_encoder(&cfg);
    let plan = lp_fusion(&g, &FusionConfig::default());
    let attn_blocks = plan
        .blocks
        .iter()
        .filter(|b| b.kind == BlockKind::AttentionCore)
        .count();
    assert!(
        attn_blocks >= 1,
        "kinds: {:?}",
        plan.blocks.iter().map(|b| b.kind).collect::<Vec<_>>()
    );
}

/// Pass pipeline is idempotent: compiling the optimized graph again
/// changes nothing.
#[test]
fn passes_idempotent_on_bert() {
    let cfg = BertConfig { vocab: 32, seq: 4, layers: 1, hidden: 8, heads: 2, inter: 16 };
    let g = build_encoder(&cfg);
    let c1 = compile(&g, &CompileOptions { model_only_tuning: true, ..Default::default() });
    let c2 = compile(&c1.graph, &CompileOptions { model_only_tuning: true, ..Default::default() });
    assert_eq!(c1.graph.num_ops(), c2.graph.num_ops());
    assert_eq!(c1.plan.num_blocks(), c2.plan.num_blocks());
}

/// Graph outputs survive every pass combination (no output is optimized
/// away or aliased to the wrong value).
#[test]
fn outputs_preserved_through_passes() {
    let cfg = BertConfig { vocab: 32, seq: 4, layers: 1, hidden: 8, heads: 2, inter: 16 };
    let g = build_encoder(&cfg);
    let c = compile(&g, &CompileOptions::default());
    assert_eq!(c.graph.outputs.len(), g.outputs.len());
    let out = &c.graph.nodes[c.graph.outputs[0]];
    assert_eq!(out.shape, g.nodes[g.outputs[0]].shape);
}
