//! Differential tests for the compression subsystem (§2.1):
//!
//! * **Pruning is exact**: a magnitude-pruned model must be *bitwise
//!   equal* to a hand-shrunk reference — the graph built directly at the
//!   smaller dims with weights sliced by independent test-local code from
//!   the same kept indices. Pruning changes *which* model runs, never
//!   *how* it runs.
//! * **INT8 is close**: quantized outputs must stay within a documented
//!   tolerance of fp32 on tiny-BERT encoders (per-channel symmetric
//!   weights + per-row dynamic activations keep the error ~1% per matmul;
//!   layernorm renormalizes between layers — we assert rtol 0.1 /
//!   atol 0.05, comfortably above observed drift, far below anything a
//!   span/argmax consumer would notice).
//! * **Executors agree under compression**: sequential vs wave-parallel
//!   execution of a compressed model stays bitwise identical at every
//!   thread count, same as the fp32 contract in `exec_differential.rs`.
//! * **Smoke** (CI): compiling + serving a tiny model with pruning+int8
//!   enabled end to end can't rot silently.

use std::collections::HashMap;

use canao::compiler::exec::interp::eval_graph;
use canao::compiler::exec::plan::execute_plan_with;
use canao::compiler::exec::Feeds;
use canao::compiler::{compile, CompileOptions};
use canao::compress::prune::{plan_prune, PruneSpec};
use canao::compress::quant::calibrate_activations;
use canao::compress::{compress_encoder, CompressionConfig};
use canao::model::{build_encoder, build_encoder_with, BertConfig, LayerDims};
use canao::util::check::assert_close;
use canao::util::rng::Rng;

fn tiny_cfg() -> BertConfig {
    BertConfig { vocab: 64, seq: 8, layers: 2, hidden: 16, heads: 4, inter: 24 }
}

// The weights under test are exactly the ones serving draws.
use canao::serving::init_weights;

/// Per-request inputs for an encoder graph.
fn request_feeds(cfg: &BertConfig, seed: u64) -> HashMap<String, Vec<f32>> {
    let mut rng = Rng::new(seed);
    let mut feeds = HashMap::new();
    feeds.insert(
        "input_ids".to_string(),
        (0..cfg.seq).map(|_| rng.below(cfg.vocab) as f32).collect(),
    );
    for l in 0..cfg.layers {
        feeds.insert(format!("mask{l}"), vec![0.0; cfg.seq]);
    }
    feeds
}

fn merged(
    a: &HashMap<String, Vec<f32>>,
    b: &HashMap<String, Vec<f32>>,
) -> HashMap<String, Vec<f32>> {
    let mut m = a.clone();
    for (k, v) in b {
        m.insert(k.clone(), v.clone());
    }
    m
}

/// Test-local weight slicing, independent of `compress::prune`'s
/// implementation: keep `heads` column blocks / `ffn` channels.
fn hand_shrink(
    cfg: &BertConfig,
    dense: &HashMap<String, Vec<f32>>,
    kept_heads: &[Vec<usize>],
    kept_ffn: &[Vec<usize>],
) -> HashMap<String, Vec<f32>> {
    let (h, i, dh) = (cfg.hidden, cfg.inter, cfg.head_dim());
    let mut out = dense.clone();
    for l in 0..cfg.layers {
        let cols: Vec<usize> =
            kept_heads[l].iter().flat_map(|&a| (a * dh)..((a + 1) * dh)).collect();
        for nm in ["wq", "wk", "wv"] {
            let w = &dense[&format!("layer{l}/{nm}")];
            let mut v = Vec::new();
            for r in 0..h {
                for &c in &cols {
                    v.push(w[r * h + c]);
                }
            }
            out.insert(format!("layer{l}/{nm}"), v);
        }
        for nm in ["bq", "bk", "bv"] {
            let w = &dense[&format!("layer{l}/{nm}")];
            out.insert(format!("layer{l}/{nm}"), cols.iter().map(|&c| w[c]).collect());
        }
        let wo = &dense[&format!("layer{l}/wo")];
        let mut v = Vec::new();
        for &r in &cols {
            v.extend_from_slice(&wo[r * h..(r + 1) * h]);
        }
        out.insert(format!("layer{l}/wo"), v);

        let w1 = &dense[&format!("layer{l}/w1")];
        let mut v = Vec::new();
        for r in 0..h {
            for &c in &kept_ffn[l] {
                v.push(w1[r * i + c]);
            }
        }
        out.insert(format!("layer{l}/w1"), v);
        let b1 = &dense[&format!("layer{l}/b1")];
        out.insert(format!("layer{l}/b1"), kept_ffn[l].iter().map(|&c| b1[c]).collect());
        let w2 = &dense[&format!("layer{l}/w2")];
        let mut v = Vec::new();
        for &r in &kept_ffn[l] {
            v.extend_from_slice(&w2[r * h..(r + 1) * h]);
        }
        out.insert(format!("layer{l}/w2"), v);
    }
    out
}

/// The pruned model is bitwise equal to the hand-shrunk reference graph:
/// same kept indices -> same sliced weights -> same interpreter output,
/// and the compiled pruned model agrees bitwise between the sequential
/// and wave-parallel executors.
#[test]
fn c1_pruned_model_bitwise_equals_hand_shrunk_reference() {
    let cfg = tiny_cfg();
    let dense_graph = build_encoder(&cfg);
    let dense_weights = init_weights(&dense_graph, 0x9A17);
    let spec = PruneSpec { head_keep: 0.5, ffn_keep: 0.5 };

    // What the subsystem prunes...
    let plan = plan_prune(&cfg, &dense_weights, &spec);
    let mut pruned_weights = dense_weights.clone();
    let (pruned_graph, report) = compress_encoder(
        &cfg,
        &mut pruned_weights,
        &CompressionConfig { prune: Some(spec), int8: false },
    );
    assert_eq!(report.layers, plan, "compress_encoder must follow the magnitude plan");

    // ...vs the hand-shrunk reference built by independent test code.
    let kept_heads: Vec<Vec<usize>> = plan.iter().map(|lp| lp.heads.clone()).collect();
    let kept_ffn: Vec<Vec<usize>> = plan.iter().map(|lp| lp.ffn.clone()).collect();
    let hand_weights = hand_shrink(&cfg, &dense_weights, &kept_heads, &kept_ffn);
    let dims: Vec<LayerDims> = plan.iter().map(|lp| lp.dims()).collect();
    let hand_graph = build_encoder_with(&cfg, &dims);

    // Weight maps agree exactly (encoder weights; embeddings untouched).
    for (name, v) in &hand_weights {
        assert_eq!(v, &pruned_weights[name], "weight {name} differs from hand slice");
    }

    // Interpreter outputs are bitwise equal.
    let request = request_feeds(&cfg, 0xF00D);
    let a = eval_graph(&pruned_graph, &merged(&pruned_weights, &request)).unwrap();
    let b = eval_graph(&hand_graph, &merged(&hand_weights, &request)).unwrap();
    assert_eq!(a[0].data, b[0].data, "pruned model != hand-shrunk reference");

    // And the compiled pruned model runs identically on both executors.
    let compiled = compile(
        &pruned_graph,
        &CompileOptions { model_only_tuning: true, ..Default::default() },
    );
    let feeds = Feeds::layered(&request, &pruned_weights);
    let seq = compiled.run_with(&feeds, None).unwrap();
    for threads in [1, 2, 4] {
        let (par, _) = compiled.run_parallel_with(&feeds, threads, None).unwrap();
        assert_eq!(par[0].data, seq[0].data, "parallel != sequential at {threads} threads");
    }
}

/// INT8 execution stays within the documented tolerance of fp32 on
/// tiny-BERT encoders, and sequential == parallel bitwise.
#[test]
fn c2_int8_within_tolerance_of_fp32() {
    for seed in [1u64, 2, 3] {
        let cfg = tiny_cfg();
        let graph = build_encoder(&cfg);
        let weights = init_weights(&graph, seed);
        let request = request_feeds(&cfg, seed.wrapping_mul(77));

        let compiled = compile(
            &graph,
            &CompileOptions {
                model_only_tuning: true,
                compression: CompressionConfig::int8_only(),
                ..Default::default()
            },
        );
        assert!(!compiled.quant_sites.is_empty());
        let qw = compiled.quantize_weights(&weights);
        assert_eq!(qw.by_node.len(), compiled.quant_sites.len());

        let feeds = Feeds::layered(&request, &weights);
        let fp32 = compiled.run_with(&feeds, None).unwrap();
        let int8_seq = compiled.run_with(&feeds, Some(&qw)).unwrap();
        // Documented tolerance: rtol 0.1, atol 0.05 (see module docs).
        assert_close(&int8_seq[0].data, &fp32[0].data, 0.1, 0.05)
            .unwrap_or_else(|e| panic!("int8 drifted from fp32 (seed {seed}): {e}"));
        // Quantization must actually change something (guards against a
        // silently-ignored table).
        assert_ne!(int8_seq[0].data, fp32[0].data);

        for threads in [1, 2, 4] {
            let (int8_par, _) = compiled.run_parallel_with(&feeds, threads, Some(&qw)).unwrap();
            assert_eq!(
                int8_par[0].data, int8_seq[0].data,
                "int8 parallel != sequential at {threads} threads (seed {seed})"
            );
        }

        // Static calibrated activation scales stay within a slightly
        // looser band (per-tensor instead of per-row).
        let mut qw_cal = qw.clone();
        let sample = merged(&weights, &request);
        calibrate_activations(
            &compiled.graph,
            &compiled.quant_sites,
            &mut qw_cal,
            std::slice::from_ref(&sample),
        )
        .unwrap();
        assert!(!qw_cal.act_scale.is_empty());
        let int8_static = compiled.run_with(&feeds, Some(&qw_cal)).unwrap();
        assert_close(&int8_static[0].data, &fp32[0].data, 0.15, 0.08)
            .unwrap_or_else(|e| panic!("calibrated int8 drifted (seed {seed}): {e}"));
    }
}

/// Pruning composed with int8: still close to the pruned fp32 model, and
/// the plain `execute_plan_with` path agrees with `Compiled::run_with`.
#[test]
fn c3_pruned_int8_composes() {
    let cfg = tiny_cfg();
    let dense = build_encoder(&cfg);
    let mut weights = init_weights(&dense, 9);
    let comp = CompressionConfig::pruned_int8(0.5, 0.5);
    let (graph, report) = compress_encoder(&cfg, &mut weights, &comp);
    assert!(report.params_after < report.params_before);

    let compiled = compile(
        &graph,
        &CompileOptions { model_only_tuning: true, compression: comp, ..Default::default() },
    );
    let qw = compiled.quantize_weights(&weights);
    let request = request_feeds(&cfg, 0xBEEF);
    let feeds = Feeds::layered(&request, &weights);

    let fp32 = compiled.run_with(&feeds, None).unwrap();
    let int8 = compiled.run_with(&feeds, Some(&qw)).unwrap();
    assert_close(&int8[0].data, &fp32[0].data, 0.1, 0.05).unwrap();

    let free_fn =
        execute_plan_with(&compiled.graph, &compiled.plan, &feeds, &compiled.schedules, Some(&qw))
            .unwrap();
    assert_eq!(free_fn[0].data, int8[0].data);
}

/// CI smoke: a tiny model with pruning+int8 enabled compiles and serves a
/// QA request end to end through the native engine (covers the engine
/// constructor, the cached PreparedExec, layered feeds, and the int8
/// kernel in one shot).
#[test]
fn c4_smoke_prune_int8_serving() {
    use canao::serving::{NativeQaEngine, QaRequest};
    use canao::tokenizer::{Tokenizer, Vocab};
    use std::sync::Arc;

    let tok = Arc::new(Tokenizer::new(Vocab::build(
        "layer fusion reduces the number of kernels and the memory traffic .",
        256,
    )));
    let cfg = BertConfig { vocab: 256, seq: 16, layers: 2, hidden: 16, heads: 4, inter: 24 };
    let engine =
        NativeQaEngine::with_compression(tok, cfg, 2, CompressionConfig::pruned_int8(0.5, 0.5));
    assert!(engine.report.params_after < engine.report.params_before);
    assert!(engine.report.size_ratio() > 1.5, "{}", engine.report.size_ratio());
    let resp = engine
        .answer(&QaRequest {
            question: "what reduces kernels ?".into(),
            context: "layer fusion reduces the number of kernels".into(),
        })
        .unwrap();
    assert!(resp.start_token <= resp.end_token);
    assert!(resp.score.is_finite());
    // Repeated requests reuse the cached PreparedExec and stay identical.
    let again = engine
        .answer(&QaRequest {
            question: "what reduces kernels ?".into(),
            context: "layer fusion reduces the number of kernels".into(),
        })
        .unwrap();
    assert_eq!((resp.start_token, resp.end_token), (again.start_token, again.end_token));
}
