//! Differential harness for the KV-cached decode subsystem.
//!
//! The contract under test: **KV-cached decode is bitwise identical to
//! full-resequence decode** at matched sampling seeds — across executor
//! thread counts (1/2/4), for fp32 and pruned+INT8 models, at the logits
//! level (f32 `==`, which only tolerates the sign of zero) and at the
//! generated-text level. Plus the edge cases both paths must share
//! (truncation, empty prompt, zero budget, cache-full stop) and the
//! per-token-work flatness acceptance criterion.

use std::collections::HashMap;
use std::sync::Arc;

use canao::compress::CompressionConfig;
use canao::decode::{DecodeError, DecodeMode};
use canao::model::BertConfig;
use canao::serving::{GenRequest, NativeGenEngine};
use canao::tokenizer::{Tokenizer, Vocab};
use canao::util::check::assert_close;

const CORPUS: &str = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word . \
                      layer fusion reduces the number of kernels .";

fn tiny_cfg() -> BertConfig {
    BertConfig { vocab: 256, seq: 12, layers: 2, hidden: 8, heads: 2, inter: 16 }
}

fn engine(threads: usize, comp: CompressionConfig) -> NativeGenEngine {
    let tok = Arc::new(Tokenizer::new(Vocab::build(CORPUS, 256)));
    NativeGenEngine::with_compression(tok, tiny_cfg(), threads, comp)
}

/// Per-step logits rows from the KV-cached session: prefill on `prompt`,
/// then one step per token in `steps`.
fn kv_logits(
    eng: &NativeGenEngine,
    threads: usize,
    prompt: &[i32],
    steps: &[i32],
) -> Vec<Vec<f32>> {
    let mut session = eng.decoder().begin(eng.weights(), threads);
    let mut rows = vec![session.prefill(prompt).unwrap().to_vec()];
    for &t in steps {
        rows.push(session.step(t).unwrap().to_vec());
    }
    session.finish();
    rows
}

/// The same rows from full-resequence forwards over growing prefixes.
fn reseq_logits(
    eng: &NativeGenEngine,
    threads: usize,
    prompt: &[i32],
    steps: &[i32],
) -> Vec<Vec<f32>> {
    let cfg = tiny_cfg();
    let (s, v) = (cfg.seq, cfg.vocab);
    let mut ids = prompt.to_vec();
    let mut rows = Vec::new();
    let mut full = vec![0.0f32; s * v];
    let mut request: HashMap<String, Vec<f32>> = HashMap::new();
    for i in 0..=steps.len() {
        if i > 0 {
            ids.push(steps[i - 1]);
        }
        let mut padded: Vec<f32> = ids.iter().map(|&t| t as f32).collect();
        padded.resize(s, 0.0);
        request.insert("input_ids".to_string(), padded);
        eng.decoder()
            .reseq_forward(&request, eng.weights(), threads, &mut full)
            .unwrap();
        rows.push(full[(ids.len() - 1) * v..ids.len() * v].to_vec());
    }
    rows
}

#[test]
fn kv_logits_bitwise_equal_full_resequence_fp32() {
    let prompt = [5i32, 9, 17];
    let steps = [3i32, 44, 7, 120];
    for threads in [1usize, 2, 4] {
        let eng = engine(threads, CompressionConfig::none());
        let kv = kv_logits(&eng, threads, &prompt, &steps);
        let rs = reseq_logits(&eng, threads, &prompt, &steps);
        assert_eq!(kv.len(), rs.len());
        for (i, (a, b)) in kv.iter().zip(&rs).enumerate() {
            assert_eq!(a, b, "logits row {i} diverged at {threads} threads (fp32)");
        }
    }
}

#[test]
fn kv_logits_bitwise_equal_full_resequence_pruned_int8() {
    let prompt = [2i32, 31];
    let steps = [8i32, 3, 90];
    for threads in [1usize, 2, 4] {
        let eng = engine(threads, CompressionConfig::pruned_int8(0.5, 0.5));
        let kv = kv_logits(&eng, threads, &prompt, &steps);
        let rs = reseq_logits(&eng, threads, &prompt, &steps);
        for (i, (a, b)) in kv.iter().zip(&rs).enumerate() {
            assert_eq!(a, b, "logits row {i} diverged at {threads} threads (pruned+int8)");
        }
    }
}

#[test]
fn generated_text_matches_across_modes_and_threads() {
    let req = GenRequest {
        prompt: "the model generates".into(),
        max_new_tokens: 6,
        temperature: 0.9, // sampling path: any logits divergence shows up
        seed: 77,
    };
    let mut texts = Vec::new();
    for threads in [1usize, 2, 4] {
        for comp in [CompressionConfig::none(), CompressionConfig::pruned_int8(0.5, 0.5)] {
            let eng = engine(threads, comp);
            let kv = eng.generate_with_mode(&req, DecodeMode::KvCache).unwrap();
            let full = eng.generate_with_mode(&req, DecodeMode::FullResequence).unwrap();
            assert_eq!(kv.text, full.text, "{comp:?} at {threads} threads");
            assert_eq!(kv.tokens_generated, full.tokens_generated);
            assert_eq!(kv.per_token_ms.len(), full.per_token_ms.len());
            texts.push((threads, comp.int8, kv.text));
        }
    }
    // Thread count never changes the text either.
    let fp32: Vec<&String> = texts.iter().filter(|t| !t.1).map(|t| &t.2).collect();
    assert!(fp32.windows(2).all(|w| w[0] == w[1]), "{fp32:?}");
}

#[test]
fn edge_cases_agree_between_modes() {
    let eng = engine(2, CompressionConfig::none());
    let seq = tiny_cfg().seq;

    // Prompt longer than seq: deterministic truncation, still generates
    // (one slot is kept free), identical in both modes.
    let long = GenRequest {
        prompt: CORPUS.into(), // tokenizes far past seq=12
        max_new_tokens: 5,
        temperature: 0.6,
        seed: 9,
    };
    let kv = eng.generate_with_mode(&long, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&long, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.text, full.text);
    assert_eq!(kv.tokens_generated, 1, "seq-1 truncation leaves one slot");
    assert_eq!(full.tokens_generated, 1);

    // Empty prompt falls back to [CLS] and still generates.
    let empty = GenRequest {
        prompt: "".into(),
        max_new_tokens: 3,
        temperature: 0.0,
        seed: 1,
    };
    let kv = eng.generate_with_mode(&empty, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&empty, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.text, full.text);
    assert_eq!(kv.tokens_generated, 3);

    // max_new_tokens = 0: no forward at all, prompt echoed back.
    let zero = GenRequest {
        prompt: "the model".into(),
        max_new_tokens: 0,
        temperature: 0.0,
        seed: 1,
    };
    let kv = eng.generate_with_mode(&zero, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&zero, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.tokens_generated, 0);
    assert_eq!(kv.per_token_ms.len(), 0);
    assert_eq!(kv.text, full.text);

    // Cache-full stop: an unbounded budget stops exactly at seq.
    let unbounded = GenRequest {
        prompt: "the model".into(),
        max_new_tokens: 1000,
        temperature: 0.4,
        seed: 4,
    };
    let kv = eng.generate_with_mode(&unbounded, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&unbounded, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.text, full.text);
    assert!(kv.tokens_generated < 1000);
    let prompt_len = 2; // "the model" -> 2 known words
    assert_eq!(kv.tokens_generated, seq - prompt_len, "fills the cache to seq");
}

#[test]
fn calibrated_decode_stays_cached_consistent_and_near_fp32() {
    let prompt = [5i32, 9];
    let steps = [3i32, 44];

    // fp32 reference rows (same dense weight draw as the int8 engine).
    let fp32 = engine(2, CompressionConfig::none());
    let fp_rows = reseq_logits(&fp32, 2, &prompt, &steps);

    // Int8 engine, warmup-calibrated to static activation scales.
    let mut int8 = engine(2, CompressionConfig::int8_only());
    let n = int8.calibrate_warmup(&["the model generates", "the quick brown fox"]).unwrap();
    assert!(n > 0, "warmup must calibrate the quantized sites");
    assert!(int8.decoder().calibrated_sites() > 0);

    // Calibrated KV-cached decode still equals calibrated full-reseq
    // bitwise (static scales are installed per weight name in BOTH
    // graphs)...
    let kv = kv_logits(&int8, 2, &prompt, &steps);
    let rs = reseq_logits(&int8, 2, &prompt, &steps);
    for (a, b) in kv.iter().zip(&rs) {
        assert_eq!(a, b, "calibration must not split the decode paths");
    }
    // ...and stays within the established int8 tolerance of fp32.
    for (q, f) in kv.iter().zip(&fp_rows) {
        assert_close(q, f, 0.1, 0.05).unwrap();
    }
}

#[test]
fn malformed_decode_requests_are_typed_errors_not_panics() {
    let eng = engine(1, CompressionConfig::none());
    let seq = tiny_cfg().seq;

    // Empty prompt.
    let mut s = eng.decoder().begin(eng.weights(), 1);
    assert_eq!(s.prefill(&[]).unwrap_err(), DecodeError::EmptyPrompt);

    // Over-length prompt (previously an assert! that killed the serving
    // process in release builds).
    let too_long = vec![1i32; seq + 1];
    assert_eq!(
        s.prefill(&too_long).unwrap_err(),
        DecodeError::PromptTooLong { len: seq + 1, seq }
    );

    // Stepping before prefill.
    assert_eq!(s.step(3).unwrap_err(), DecodeError::NotPrefilled);
    s.finish();

    // Stepping past a full cache.
    let mut s = eng.decoder().begin(eng.weights(), 1);
    s.prefill(&[5, 9]).unwrap();
    for t in 0..(seq - 2) {
        s.step(t as i32).unwrap();
    }
    assert_eq!(s.step(7).unwrap_err(), DecodeError::CacheFull { seq });
    s.finish();
}

#[test]
fn full_length_prompt_scores_without_stepping() {
    // A prompt that fills the whole sequence is a legit scoring request:
    // prefill succeeds, its last logits row equals the full-resequence
    // forward's bitwise, and any subsequent step reports CacheFull.
    let eng = engine(2, CompressionConfig::none());
    let seq = tiny_cfg().seq;
    let prompt: Vec<i32> = (0..seq as i32).map(|i| (i * 13 + 5) % 200).collect();

    let mut s = eng.decoder().begin(eng.weights(), 2);
    let prefill_row = s.prefill(&prompt).unwrap().to_vec();
    assert_eq!(s.step(1).unwrap_err(), DecodeError::CacheFull { seq });
    s.finish();

    let rs = reseq_logits(&eng, 2, &prompt, &[]);
    assert_eq!(prefill_row, rs[0], "scoring prefill != full forward");
}

#[test]
fn decode_graphs_run_zero_int8_matmul_fallbacks() {
    // The fused matmul+layernorm kernel covers wo/w2 in BOTH decode
    // graphs; with pruning+int8 the only non-fused quantized dispatch is
    // the LM head's direct single-op block.
    let eng = engine(2, CompressionConfig::pruned_int8(0.5, 0.5));
    let (pc, sc) = eng.decoder().dispatch_counts();
    assert_eq!(pc.fallback_i8_matmul, 0, "prefill: {pc}");
    assert_eq!(sc.fallback_i8_matmul, 0, "step: {sc}");
    assert!(pc.fused_layernorm_i8 > 0 && sc.fused_layernorm_i8 > 0);

    // fp32 engines run the fused fp32 layernorm kernel instead.
    let fp = engine(1, CompressionConfig::none());
    let (pc, sc) = fp.decoder().dispatch_counts();
    assert!(pc.fused_layernorm_f32 > 0 && sc.fused_layernorm_f32 > 0);
    assert_eq!(pc.fallback_i8_matmul + sc.fallback_i8_matmul, 0);
}

#[test]
fn per_token_executor_work_is_flat() {
    let eng = engine(2, CompressionConfig::none());
    let mut session = eng.decoder().begin(eng.weights(), 2);
    session.prefill(&[5, 9, 17]).unwrap();
    let prefill_stats = session.last_stats().unwrap();

    let mut step_stats = Vec::new();
    for t in [3i32, 44, 7, 120, 6] {
        session.step(t).unwrap();
        step_stats.push(session.last_stats().unwrap());
    }
    session.finish();

    // Acceptance: the step's executor work does not scale with the
    // number of previously generated tokens — every step runs the same
    // waves over the same arena footprint...
    for s in &step_stats {
        assert_eq!(s.waves, step_stats[0].waves);
        assert_eq!(s.naive_bytes, step_stats[0].naive_bytes);
        assert_eq!(s.peak_arena_bytes, step_stats[0].peak_arena_bytes);
    }
    // ...and that footprint is well below one full-sequence forward's.
    assert!(
        step_stats[0].naive_bytes * 2 < prefill_stats.naive_bytes,
        "step {} bytes !<< prefill {} bytes",
        step_stats[0].naive_bytes,
        prefill_stats.naive_bytes
    );
}
