//! Differential harness for the KV-cached decode subsystem.
//!
//! The contract under test: **KV-cached decode is bitwise identical to
//! full-resequence decode** at matched sampling seeds — across executor
//! thread counts (1/2/4), for fp32 and pruned+INT8 models, at the logits
//! level (f32 `==`, which only tolerates the sign of zero) and at the
//! generated-text level. Plus the edge cases both paths must share
//! (truncation, empty prompt, zero budget, cache-full stop) and the
//! per-token-work flatness acceptance criterion.

use std::collections::HashMap;
use std::sync::Arc;

use canao::compress::CompressionConfig;
use canao::decode::{BatchSlot, BatchStepper, DecodeError, DecodeMode};
use canao::model::BertConfig;
use canao::serving::{GenRequest, NativeGenEngine};
use canao::tokenizer::{Tokenizer, Vocab};
use canao::util::check::assert_close;

const CORPUS: &str = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word . \
                      layer fusion reduces the number of kernels .";

fn tiny_cfg() -> BertConfig {
    BertConfig { vocab: 256, seq: 12, layers: 2, hidden: 8, heads: 2, inter: 16 }
}

fn engine(threads: usize, comp: CompressionConfig) -> NativeGenEngine {
    let tok = Arc::new(Tokenizer::new(Vocab::build(CORPUS, 256)));
    NativeGenEngine::with_compression(tok, tiny_cfg(), threads, comp)
}

/// Per-step logits rows from the KV-cached session: prefill on `prompt`,
/// then one step per token in `steps`.
fn kv_logits(
    eng: &NativeGenEngine,
    threads: usize,
    prompt: &[i32],
    steps: &[i32],
) -> Vec<Vec<f32>> {
    let mut session = eng.decoder().begin(eng.weights(), threads);
    let mut rows = vec![session.prefill(prompt).unwrap().to_vec()];
    for &t in steps {
        rows.push(session.step(t).unwrap().to_vec());
    }
    session.finish();
    rows
}

/// The same rows from full-resequence forwards over growing prefixes.
fn reseq_logits(
    eng: &NativeGenEngine,
    threads: usize,
    prompt: &[i32],
    steps: &[i32],
) -> Vec<Vec<f32>> {
    let cfg = tiny_cfg();
    let (s, v) = (cfg.seq, cfg.vocab);
    let mut ids = prompt.to_vec();
    let mut rows = Vec::new();
    let mut full = vec![0.0f32; s * v];
    let mut request: HashMap<String, Vec<f32>> = HashMap::new();
    for i in 0..=steps.len() {
        if i > 0 {
            ids.push(steps[i - 1]);
        }
        let mut padded: Vec<f32> = ids.iter().map(|&t| t as f32).collect();
        padded.resize(s, 0.0);
        request.insert("input_ids".to_string(), padded);
        eng.decoder()
            .reseq_forward(&request, eng.weights(), threads, &mut full)
            .unwrap();
        rows.push(full[(ids.len() - 1) * v..ids.len() * v].to_vec());
    }
    rows
}

#[test]
fn kv_logits_bitwise_equal_full_resequence_fp32() {
    let prompt = [5i32, 9, 17];
    let steps = [3i32, 44, 7, 120];
    for threads in [1usize, 2, 4] {
        let eng = engine(threads, CompressionConfig::none());
        let kv = kv_logits(&eng, threads, &prompt, &steps);
        let rs = reseq_logits(&eng, threads, &prompt, &steps);
        assert_eq!(kv.len(), rs.len());
        for (i, (a, b)) in kv.iter().zip(&rs).enumerate() {
            assert_eq!(a, b, "logits row {i} diverged at {threads} threads (fp32)");
        }
    }
}

#[test]
fn kv_logits_bitwise_equal_full_resequence_pruned_int8() {
    let prompt = [2i32, 31];
    let steps = [8i32, 3, 90];
    for threads in [1usize, 2, 4] {
        let eng = engine(threads, CompressionConfig::pruned_int8(0.5, 0.5));
        let kv = kv_logits(&eng, threads, &prompt, &steps);
        let rs = reseq_logits(&eng, threads, &prompt, &steps);
        for (i, (a, b)) in kv.iter().zip(&rs).enumerate() {
            assert_eq!(a, b, "logits row {i} diverged at {threads} threads (pruned+int8)");
        }
    }
}

#[test]
fn generated_text_matches_across_modes_and_threads() {
    let req = GenRequest {
        prompt: "the model generates".into(),
        max_new_tokens: 6,
        temperature: 0.9, // sampling path: any logits divergence shows up
        seed: 77,
    };
    let mut texts = Vec::new();
    for threads in [1usize, 2, 4] {
        for comp in [CompressionConfig::none(), CompressionConfig::pruned_int8(0.5, 0.5)] {
            let eng = engine(threads, comp);
            let kv = eng.generate_with_mode(&req, DecodeMode::KvCache).unwrap();
            let full = eng.generate_with_mode(&req, DecodeMode::FullResequence).unwrap();
            assert_eq!(kv.text, full.text, "{comp:?} at {threads} threads");
            assert_eq!(kv.tokens_generated, full.tokens_generated);
            assert_eq!(kv.per_token_ms.len(), full.per_token_ms.len());
            texts.push((threads, comp.int8, kv.text));
        }
    }
    // Thread count never changes the text either.
    let fp32: Vec<&String> = texts.iter().filter(|t| !t.1).map(|t| &t.2).collect();
    assert!(fp32.windows(2).all(|w| w[0] == w[1]), "{fp32:?}");
}

#[test]
fn edge_cases_agree_between_modes() {
    let eng = engine(2, CompressionConfig::none());
    let seq = tiny_cfg().seq;

    // Prompt longer than seq: deterministic truncation, still generates
    // (one slot is kept free), identical in both modes.
    let long = GenRequest {
        prompt: CORPUS.into(), // tokenizes far past seq=12
        max_new_tokens: 5,
        temperature: 0.6,
        seed: 9,
    };
    let kv = eng.generate_with_mode(&long, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&long, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.text, full.text);
    assert_eq!(kv.tokens_generated, 1, "seq-1 truncation leaves one slot");
    assert_eq!(full.tokens_generated, 1);

    // Empty prompt falls back to [CLS] and still generates.
    let empty = GenRequest {
        prompt: "".into(),
        max_new_tokens: 3,
        temperature: 0.0,
        seed: 1,
    };
    let kv = eng.generate_with_mode(&empty, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&empty, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.text, full.text);
    assert_eq!(kv.tokens_generated, 3);

    // max_new_tokens = 0: no forward at all, prompt echoed back.
    let zero = GenRequest {
        prompt: "the model".into(),
        max_new_tokens: 0,
        temperature: 0.0,
        seed: 1,
    };
    let kv = eng.generate_with_mode(&zero, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&zero, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.tokens_generated, 0);
    assert_eq!(kv.per_token_ms.len(), 0);
    assert_eq!(kv.text, full.text);

    // Cache-full stop: an unbounded budget stops exactly at seq.
    let unbounded = GenRequest {
        prompt: "the model".into(),
        max_new_tokens: 1000,
        temperature: 0.4,
        seed: 4,
    };
    let kv = eng.generate_with_mode(&unbounded, DecodeMode::KvCache).unwrap();
    let full = eng.generate_with_mode(&unbounded, DecodeMode::FullResequence).unwrap();
    assert_eq!(kv.text, full.text);
    assert!(kv.tokens_generated < 1000);
    let prompt_len = 2; // "the model" -> 2 known words
    assert_eq!(kv.tokens_generated, seq - prompt_len, "fills the cache to seq");
}

#[test]
fn calibrated_decode_stays_cached_consistent_and_near_fp32() {
    let prompt = [5i32, 9];
    let steps = [3i32, 44];

    // fp32 reference rows (same dense weight draw as the int8 engine).
    let fp32 = engine(2, CompressionConfig::none());
    let fp_rows = reseq_logits(&fp32, 2, &prompt, &steps);

    // Int8 engine, warmup-calibrated to static activation scales.
    let mut int8 = engine(2, CompressionConfig::int8_only());
    let n = int8.calibrate_warmup(&["the model generates", "the quick brown fox"]).unwrap();
    assert!(n > 0, "warmup must calibrate the quantized sites");
    assert!(int8.decoder().calibrated_sites() > 0);

    // Calibrated KV-cached decode still equals calibrated full-reseq
    // bitwise (static scales are installed per weight name in BOTH
    // graphs)...
    let kv = kv_logits(&int8, 2, &prompt, &steps);
    let rs = reseq_logits(&int8, 2, &prompt, &steps);
    for (a, b) in kv.iter().zip(&rs) {
        assert_eq!(a, b, "calibration must not split the decode paths");
    }
    // ...and stays within the established int8 tolerance of fp32.
    for (q, f) in kv.iter().zip(&fp_rows) {
        assert_close(q, f, 0.1, 0.05).unwrap();
    }
}

#[test]
fn malformed_decode_requests_are_typed_errors_not_panics() {
    let eng = engine(1, CompressionConfig::none());
    let seq = tiny_cfg().seq;

    // Empty prompt.
    let mut s = eng.decoder().begin(eng.weights(), 1);
    assert_eq!(s.prefill(&[]).unwrap_err(), DecodeError::EmptyPrompt);

    // Over-length prompt (previously an assert! that killed the serving
    // process in release builds).
    let too_long = vec![1i32; seq + 1];
    assert_eq!(
        s.prefill(&too_long).unwrap_err(),
        DecodeError::PromptTooLong { len: seq + 1, seq }
    );

    // Stepping before prefill.
    assert_eq!(s.step(3).unwrap_err(), DecodeError::NotPrefilled);
    s.finish();

    // Stepping past a full cache.
    let mut s = eng.decoder().begin(eng.weights(), 1);
    s.prefill(&[5, 9]).unwrap();
    for t in 0..(seq - 2) {
        s.step(t as i32).unwrap();
    }
    assert_eq!(s.step(7).unwrap_err(), DecodeError::CacheFull { seq });
    s.finish();
}

#[test]
fn full_length_prompt_scores_without_stepping() {
    // A prompt that fills the whole sequence is a legit scoring request:
    // prefill succeeds, its last logits row equals the full-resequence
    // forward's bitwise, and any subsequent step reports CacheFull.
    let eng = engine(2, CompressionConfig::none());
    let seq = tiny_cfg().seq;
    let prompt: Vec<i32> = (0..seq as i32).map(|i| (i * 13 + 5) % 200).collect();

    let mut s = eng.decoder().begin(eng.weights(), 2);
    let prefill_row = s.prefill(&prompt).unwrap().to_vec();
    assert_eq!(s.step(1).unwrap_err(), DecodeError::CacheFull { seq });
    s.finish();

    let rs = reseq_logits(&eng, 2, &prompt, &[]);
    assert_eq!(prefill_row, rs[0], "scoring prefill != full forward");
}

#[test]
fn decode_graphs_run_zero_int8_matmul_fallbacks() {
    // The fused matmul+layernorm kernel covers wo/w2 in BOTH decode
    // graphs; with pruning+int8 the only non-fused quantized dispatch is
    // the LM head's direct single-op block.
    let eng = engine(2, CompressionConfig::pruned_int8(0.5, 0.5));
    let (pc, sc) = eng.decoder().dispatch_counts();
    assert_eq!(pc.fallback_i8_matmul, 0, "prefill: {pc}");
    assert_eq!(sc.fallback_i8_matmul, 0, "step: {sc}");
    assert!(pc.fused_layernorm_i8 > 0 && sc.fused_layernorm_i8 > 0);

    // fp32 engines run the fused fp32 layernorm kernel instead.
    let fp = engine(1, CompressionConfig::none());
    let (pc, sc) = fp.decoder().dispatch_counts();
    assert!(pc.fused_layernorm_f32 > 0 && sc.fused_layernorm_f32 > 0);
    assert_eq!(pc.fallback_i8_matmul + sc.fallback_i8_matmul, 0);
}

#[test]
fn batched_step_rows_bitwise_equal_batch1() {
    // Four sessions with different prompts and token streams, stepped
    // together through the batched step graph: every slot's logits row
    // must equal the batch-1 session's row bitwise (f32 `==`), across
    // thread counts and under pruning + INT8. This is the contract that
    // makes continuous batching free of any quality trade.
    let prompts: [&[i32]; 4] = [&[5, 9, 17], &[2, 31], &[7], &[40, 8, 3, 99]];
    let steps: [&[i32]; 4] = [&[3, 44, 7], &[8, 3, 90], &[120, 6, 11], &[1, 2, 200]];
    for comp in [CompressionConfig::none(), CompressionConfig::pruned_int8(0.5, 0.5)] {
        for threads in [1usize, 2, 4] {
            let mut eng = engine(threads, comp);
            eng.enable_batched(4);
            let reference: Vec<Vec<Vec<f32>>> =
                (0..4).map(|i| kv_logits(&eng, threads, prompts[i], steps[i])).collect();

            let dec = eng.decoder();
            let cfg = tiny_cfg();
            let mut caches: Vec<_> = (0..4).map(|_| dec.new_cache().unwrap()).collect();
            let mut prefill = vec![0.0f32; cfg.seq * cfg.vocab];
            for (i, c) in caches.iter_mut().enumerate() {
                let len =
                    dec.prefill_into(prompts[i], c, &mut prefill, eng.weights(), threads).unwrap();
                assert_eq!(len, prompts[i].len());
            }
            let mut stepper = BatchStepper::new(dec);
            for t in 0..3 {
                let mut slots: Vec<BatchSlot> = caches
                    .iter_mut()
                    .enumerate()
                    .map(|(i, c)| {
                        let pos = c.len;
                        BatchSlot { cache: c, token: steps[i][t], pos }
                    })
                    .collect();
                let b = stepper.step(dec, eng.weights(), threads, &mut slots).unwrap();
                assert_eq!(b, 4, "full wave dispatches the b=4 rung");
                for i in 0..4 {
                    assert_eq!(
                        stepper.logits_row(i),
                        reference[i][t + 1].as_slice(),
                        "slot {i} wave {t} diverged at {threads} threads ({comp:?})"
                    );
                }
            }
            for c in caches {
                dec.release_cache(c);
            }
        }
    }
}

#[test]
fn partial_waves_with_dummy_lanes_and_retirement_stay_bitwise() {
    // 3 active slots on a b=4 rung (one dummy lane), then a mid-flight
    // retirement shrinking the wave to the b=2 and b=1 rungs: dummy
    // lanes and rung switches must never perturb active slots.
    let prompts: [&[i32]; 3] = [&[5, 9], &[2, 31, 7], &[40]];
    let steps: [&[i32]; 3] = [&[3, 44, 7], &[8], &[120, 6]];
    let eng = {
        let mut e = engine(2, CompressionConfig::none());
        e.enable_batched(4);
        e
    };
    let reference: Vec<Vec<Vec<f32>>> =
        (0..3).map(|i| kv_logits(&eng, 2, prompts[i], steps[i])).collect();

    let dec = eng.decoder();
    let cfg = tiny_cfg();
    let mut prefill = vec![0.0f32; cfg.seq * cfg.vocab];
    let mut caches: Vec<_> = (0..3).map(|_| dec.new_cache().unwrap()).collect();
    for (i, c) in caches.iter_mut().enumerate() {
        dec.prefill_into(prompts[i], c, &mut prefill, eng.weights(), 2).unwrap();
    }
    let mut stepper = BatchStepper::new(dec);

    // Wave 1: all three active -> rung 4, one dummy lane.
    {
        let mut it = caches.iter_mut();
        let (c0, c1, c2) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut slots = [
            BatchSlot { pos: c0.len, cache: c0, token: steps[0][0] },
            BatchSlot { pos: c1.len, cache: c1, token: steps[1][0] },
            BatchSlot { pos: c2.len, cache: c2, token: steps[2][0] },
        ];
        let b = stepper.step(dec, eng.weights(), 2, &mut slots).unwrap();
        assert_eq!(b, 4, "3 active slots round up to the b=4 rung");
        for i in 0..3 {
            assert_eq!(stepper.logits_row(i), reference[i][1].as_slice(), "wave 1 slot {i}");
        }
    }

    // Slot 1 finished: its pages go back without copying...
    let retired = caches.remove(1);
    dec.release_cache(retired);

    // ...and the survivors keep stepping, now on the b=2 rung.
    {
        let mut it = caches.iter_mut();
        let (c0, c2) = (it.next().unwrap(), it.next().unwrap());
        let mut slots = [
            BatchSlot { pos: c0.len, cache: c0, token: steps[0][1] },
            BatchSlot { pos: c2.len, cache: c2, token: steps[2][1] },
        ];
        let b = stepper.step(dec, eng.weights(), 2, &mut slots).unwrap();
        assert_eq!(b, 2);
        assert_eq!(stepper.logits_row(0), reference[0][2].as_slice(), "wave 2 slot 0");
        assert_eq!(stepper.logits_row(1), reference[2][2].as_slice(), "wave 2 slot 2");
    }

    // Down to one session: the b=1 rung.
    {
        let c0 = &mut caches[0];
        let mut slots = [BatchSlot { pos: c0.len, cache: c0, token: steps[0][2] }];
        let b = stepper.step(dec, eng.weights(), 2, &mut slots).unwrap();
        assert_eq!(b, 1);
        assert_eq!(stepper.logits_row(0), reference[0][3].as_slice(), "wave 3 slot 0");
    }
    for c in caches {
        dec.release_cache(c);
    }
}

#[test]
fn phase_timed_batched_stepper_is_bitwise_equal_to_untimed() {
    // PR 9: the decode-phase split on the batched path only brackets the
    // wave with clock reads — rows must stay bitwise identical to the
    // untimed stepper, and the accounting must reconcile with the waves
    // actually dispatched.
    let prompts: [&[i32]; 2] = [&[5, 9, 17], &[2, 31]];
    let steps: [&[i32]; 2] = [&[3, 44, 7], &[8, 3, 90]];
    let mut eng = engine(2, CompressionConfig::none());
    eng.enable_batched(2);
    let reference: Vec<Vec<Vec<f32>>> =
        (0..2).map(|i| kv_logits(&eng, 2, prompts[i], steps[i])).collect();

    let dec = eng.decoder();
    let cfg = tiny_cfg();
    let mut prefill = vec![0.0f32; cfg.seq * cfg.vocab];
    let mut caches: Vec<_> = (0..2).map(|_| dec.new_cache().unwrap()).collect();
    for (i, c) in caches.iter_mut().enumerate() {
        dec.prefill_into(prompts[i], c, &mut prefill, eng.weights(), 2).unwrap();
    }
    let mut stepper = BatchStepper::new(dec);
    stepper.enable_phase_timing();
    for t in 0..3 {
        let mut slots: Vec<BatchSlot> = caches
            .iter_mut()
            .enumerate()
            .map(|(i, c)| {
                let pos = c.len;
                BatchSlot { cache: c, token: steps[i][t], pos }
            })
            .collect();
        stepper.step(dec, eng.weights(), 2, &mut slots).unwrap();
        for i in 0..2 {
            assert_eq!(
                stepper.logits_row(i),
                reference[i][t + 1].as_slice(),
                "slot {i} wave {t}: phase timing perturbed the logits"
            );
        }
    }
    let phases = stepper.take_phases();
    assert_eq!(phases.steps, 6, "3 waves x 2 active slots, counted per token");
    assert!(phases.step_compute_ns > 0, "wave compute was timed");
    assert!(phases.prefill_ns == 0, "the stepper never prefills");
    assert_eq!(stepper.phases().steps, 0, "take_phases resets the accumulator");
    for c in caches {
        dec.release_cache(c);
    }
}

#[test]
fn batched_step_graphs_run_zero_int8_fallbacks() {
    // Acceptance: the whole batched ladder dispatches through the fused
    // int8 kernels — no per-node interpreter fallbacks crept in with the
    // gather/scatter/slice/concat batching ops.
    let mut eng = engine(2, CompressionConfig::pruned_int8(0.5, 0.5));
    eng.enable_batched(4);
    let census = eng.decoder().batched_dispatch_counts();
    assert_eq!(census.len(), 3, "ladder rungs 1, 2, 4");
    for (b, c) in census {
        assert_eq!(c.fallback_i8_matmul, 0, "rung {b}: {c}");
        assert!(c.fused_layernorm_i8 > 0, "rung {b} runs the fused int8 kernel");
    }
}

#[test]
fn rollback_replays_identical_logits() {
    // Speculative-decoding building block: rewind a session to an
    // earlier position and re-decode — the replayed rows must be bitwise
    // identical to the first pass (truncate_to leaves no stale state).
    let eng = engine(2, CompressionConfig::none());
    let mut s = eng.decoder().begin(eng.weights(), 2);
    s.prefill(&[5, 9, 17]).unwrap();
    let base = s.position();
    let tokens = [3i32, 44, 7];
    let first: Vec<Vec<f32>> =
        tokens.iter().map(|&t| s.step(t).unwrap().to_vec()).collect();

    // Full rollback to the prompt, replay the same tokens.
    s.rollback_to(base);
    assert_eq!(s.position(), base);
    let replay: Vec<Vec<f32>> =
        tokens.iter().map(|&t| s.step(t).unwrap().to_vec()).collect();
    assert_eq!(first, replay, "full-rollback replay diverged");

    // Partial rollback: keep the first accepted token, replay the rest.
    s.rollback_to(base + 1);
    assert_eq!(s.position(), base + 1);
    let tail: Vec<Vec<f32>> =
        tokens[1..].iter().map(|&t| s.step(t).unwrap().to_vec()).collect();
    assert_eq!(&first[1..], tail.as_slice(), "partial-rollback replay diverged");

    // Rolling back never *extends* the session.
    s.rollback_to(usize::MAX);
    assert_eq!(s.position(), base + tokens.len());
    s.finish();
}

#[test]
fn per_token_executor_work_is_flat() {
    let eng = engine(2, CompressionConfig::none());
    let mut session = eng.decoder().begin(eng.weights(), 2);
    session.prefill(&[5, 9, 17]).unwrap();
    let prefill_stats = session.last_stats().unwrap();

    let mut step_stats = Vec::new();
    for t in [3i32, 44, 7, 120, 6] {
        session.step(t).unwrap();
        step_stats.push(session.last_stats().unwrap());
    }
    session.finish();

    // Acceptance: the step's executor work does not scale with the
    // number of previously generated tokens — every step runs the same
    // waves over the same arena footprint...
    for s in &step_stats {
        assert_eq!(s.waves, step_stats[0].waves);
        assert_eq!(s.naive_bytes, step_stats[0].naive_bytes);
        assert_eq!(s.peak_arena_bytes, step_stats[0].peak_arena_bytes);
    }
    // ...and that footprint is well below one full-sequence forward's.
    assert!(
        step_stats[0].naive_bytes * 2 < prefill_stats.naive_bytes,
        "step {} bytes !<< prefill {} bytes",
        step_stats[0].naive_bytes,
        prefill_stats.naive_bytes
    );
}
