//! Differential test harness for the executors (via the in-tree `forall`
//! substrate): for randomly generated graphs — elementwise/broadcast/
//! reduce DAGs, attention-shaped graphs, and whole tiny BERT encoders —
//! and for every fusion budget, schedule variant, and thread count, the
//! three executors must agree:
//!
//!   interp::eval_graph  ==  plan::execute_plan  ==  execute_plan_parallel
//!
//! Sequential-vs-parallel agreement is asserted BITWISE (they run the
//! same tapes and native kernels in the same per-element order); both are
//! compared to the interpreter with tolerance (fused kernels reassociate).
//!
//! The generators extend `proptest_invariants.rs`'s `random_graph` with
//! matmul/transpose/softmax structure so every block kind — tape,
//! native softmax/layernorm, attention-core fallback — is exercised.

use std::collections::HashMap;

use canao::compiler::exec::interp::eval_graph;
use canao::compiler::exec::parallel::{
    block_waves, execute_plan_parallel, execute_plan_parallel_stats,
    execute_prepared_sinks_profiled, PreparedExec,
};
use canao::compiler::exec::plan::execute_plan;
use canao::compiler::exec::{ExecError, Feeds, OutputSink, Profiler, WorkerPool};
use canao::compiler::fusion::{lp_fusion, FusionConfig, FusionPlan};
use canao::compiler::ir::{DType, Graph, Op};
use canao::compiler::poly::Schedule;
use canao::compiler::{compile, CompileOptions};
use canao::compress::CompressionConfig;
use canao::model::{build_encoder, BertConfig};
use canao::util::check::{assert_close, forall};
use canao::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Random elementwise/broadcast/reduce DAG (the `proptest_invariants.rs`
/// generator, extended with an occasional matmul-through-transpose pair
/// so non-fusable and fallback blocks appear).
fn random_graph(rng: &mut Rng) -> Graph {
    let mut g = Graph::new();
    let m = 2 + rng.below(6);
    let n = 2 + rng.below(6);
    let full = g.input("x0", &[m, n], DType::F32);
    let row = g.input("x1", &[n], DType::F32);
    let full2 = g.weight("w0", &[m, n]);
    let mut values = vec![full, row, full2];

    // Side branches whose shapes ([k,k]) would break the broadcast pool:
    // they become extra graph outputs instead of new operands.
    let mut extras: Vec<usize> = Vec::new();

    let n_ops = 3 + rng.below(10);
    for _ in 0..n_ops {
        let a = *rng.choose(&values);
        let b = *rng.choose(&values);
        let choice = rng.below(10);
        match choice {
            0 => values.push(g.add(a, b)),
            1 => values.push(g.mul(a, b)),
            2 => values.push(g.sub(a, b)),
            3 => values.push(g.add_op(Op::Tanh, &[a])),
            4 => values.push(g.add_op(Op::Exp, &[a])),
            5 => {
                let c = g.constant(0.5 + rng.f32());
                values.push(g.mul(a, c));
            }
            6 => {
                // max-based (softmax-ish) fragment
                let r = g.add_op(Op::ReduceMax { axis: g.nodes[a].shape.rank() - 1 }, &[a]);
                values.push(g.sub(a, r));
            }
            7 => values.push(g.add_op(Op::Max, &[a, b])),
            8 => {
                // full softmax over the last axis: native-kernel block
                values.push(g.softmax(a, g.nodes[a].shape.rank() - 1));
            }
            _ => {
                if g.nodes[a].shape.rank() == 2 {
                    // attention-ish: transpose (unfusable) + matmul
                    // (fallback block) + softmax over the [k,k] scores
                    let at = g.add_op(Op::Transpose, &[a]);
                    let mm = g.matmul(a, at);
                    extras.push(g.softmax(mm, 1));
                } else {
                    values.push(g.add(a, b));
                }
            }
        }
    }
    // 1-2 outputs from the op results (never the raw leaves).
    let mut candidates: Vec<usize> = values[3..].to_vec();
    candidates.extend(extras.iter().copied());
    let o1 = *rng.choose(&candidates);
    g.mark_output(o1);
    if rng.below(2) == 0 {
        let o2 = *rng.choose(&candidates);
        if o2 != o1 {
            g.mark_output(o2);
        }
    }
    g
}

fn feeds_for(g: &Graph, rng: &mut Rng) -> HashMap<String, Vec<f32>> {
    let mut feeds = HashMap::new();
    for node in &g.nodes {
        if let Op::Input { name } | Op::Weight { name } = &node.op {
            feeds.insert(
                name.clone(),
                (0..node.shape.numel()).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
        }
    }
    feeds
}

/// Force every block of the plan to one schedule (blocks whose domain
/// isn't 2-D simply ignore the hoisted choice — also worth covering).
fn force_schedule(plan: &FusionPlan, sched: Schedule) -> HashMap<usize, Schedule> {
    plan.blocks.iter().map(|b| (b.id, sched)).collect()
}

fn check_all_executors(
    g: &Graph,
    plan: &FusionPlan,
    feeds: &HashMap<String, Vec<f32>>,
    schedules: &HashMap<usize, Schedule>,
) -> Result<(), String> {
    let expect = eval_graph(g, feeds).map_err(|e| e.to_string())?;
    let seq = execute_plan(g, plan, feeds, schedules).map_err(|e| e.to_string())?;
    if seq.len() != expect.len() {
        return Err(format!("output count {} vs {}", seq.len(), expect.len()));
    }
    for (s, e) in seq.iter().zip(&expect) {
        assert_close(&s.data, &e.data, 1e-4, 1e-5)?;
    }
    for &threads in &THREAD_COUNTS {
        let par = execute_plan_parallel(g, plan, feeds, schedules, threads)
            .map_err(|e| e.to_string())?;
        for (i, (p, s)) in par.iter().zip(&seq).enumerate() {
            if p.data != s.data {
                return Err(format!(
                    "output {i}: parallel({threads} threads) differs bitwise from sequential"
                ));
            }
            if p.shape != s.shape {
                return Err(format!("output {i}: shape mismatch"));
            }
        }
    }
    Ok(())
}

#[test]
fn d1_random_graphs_all_executors_agree() {
    forall(
        0xD1FF,
        50,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            let budget = if rng.below(2) == 0 { 1 << 26 } else { 256 };
            (g, feeds, budget)
        },
        |(g, feeds, budget)| {
            let cfg = FusionConfig { footprint_budget: *budget, ..Default::default() };
            let plan = lp_fusion(g, &cfg);
            check_all_executors(g, &plan, feeds, &HashMap::new())
        },
    );
}

#[test]
fn d2_every_schedule_variant_agrees() {
    forall(
        0x5C4E,
        30,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            (g, feeds)
        },
        |(g, feeds)| {
            let plan = lp_fusion(g, &FusionConfig::default());
            for sched in [Schedule::RowRecompute, Schedule::HoistedColMajor] {
                let choices = force_schedule(&plan, sched);
                check_all_executors(g, &plan, feeds, &choices)?;
            }
            Ok(())
        },
    );
}

#[test]
fn d3_disabled_fusion_agrees() {
    forall(
        0x0FF,
        25,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            (g, feeds)
        },
        |(g, feeds)| {
            let plan = lp_fusion(g, &FusionConfig::disabled());
            check_all_executors(g, &plan, feeds, &HashMap::new())
        },
    );
}

/// Whole tiny BERT encoders: attention cores, layernorms, GELU, residual
/// structure — the real op stream the serving path executes.
#[test]
fn d4_tiny_bert_encoders_agree() {
    forall(
        0xBE47,
        6,
        |rng| {
            let heads = 1 + rng.below(2);
            let cfg = BertConfig {
                vocab: 32 + rng.below(64),
                seq: 2 + rng.below(6),
                layers: 1 + rng.below(2),
                hidden: heads * (4 + rng.below(3) * 4),
                heads,
                inter: 8 + rng.below(24),
            };
            let g = build_encoder(&cfg);
            let mut feeds = HashMap::new();
            for node in &g.nodes {
                if let Op::Input { name } | Op::Weight { name } = &node.op {
                    let v = if name.starts_with("mask") {
                        vec![0.0; node.shape.numel()]
                    } else if name.ends_with("gamma") {
                        vec![1.0; node.shape.numel()]
                    } else if node.dtype == DType::I32 {
                        (0..node.shape.numel())
                            .map(|_| rng.below(32) as f32)
                            .collect()
                    } else {
                        (0..node.shape.numel())
                            .map(|_| rng.normal_f32(0.0, 0.05))
                            .collect()
                    };
                    feeds.insert(name.clone(), v);
                }
            }
            (g, feeds)
        },
        |(g, feeds)| {
            let plan = lp_fusion(g, &FusionConfig::default());
            let expect = eval_graph(g, feeds).map_err(|e| e.to_string())?;
            let seq = execute_plan(g, &plan, feeds, &HashMap::new()).map_err(|e| e.to_string())?;
            assert_close(&seq[0].data, &expect[0].data, 2e-3, 2e-3)?;
            for &threads in &THREAD_COUNTS {
                let par = execute_plan_parallel(g, &plan, feeds, &HashMap::new(), threads)
                    .map_err(|e| e.to_string())?;
                if par[0].data != seq[0].data {
                    return Err(format!("{threads}-thread run differs from sequential"));
                }
            }
            Ok(())
        },
    );
}

/// The arena invariants under load: peak <= naive on every random graph,
/// and the wave partition respects block dependencies.
#[test]
fn d5_arena_and_waves_invariants() {
    forall(
        0xA4E4A,
        40,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            (g, feeds)
        },
        |(g, feeds)| {
            let plan = lp_fusion(g, &FusionConfig::default());
            let waves = block_waves(&plan);
            let mut wave_of = vec![0usize; plan.blocks.len()];
            for (w, bs) in waves.iter().enumerate() {
                for &b in bs {
                    wave_of[b] = w;
                }
            }
            for (bi, block) in plan.blocks.iter().enumerate() {
                for inp in &block.inputs {
                    if let Some(&src) = plan.block_of.get(inp) {
                        if wave_of[src] >= wave_of[bi] {
                            return Err(format!(
                                "block {bi} in wave {} reads block {src} in wave {}",
                                wave_of[bi], wave_of[src]
                            ));
                        }
                    }
                }
            }
            let (_, stats) = execute_plan_parallel_stats(g, &plan, feeds, &HashMap::new(), 2)
                .map_err(|e| e.to_string())?;
            if stats.peak_arena_bytes > stats.naive_bytes {
                return Err(format!(
                    "arena peak {} exceeds per-node baseline {}",
                    stats.peak_arena_bytes, stats.naive_bytes
                ));
            }
            if stats.slab_bytes < stats.peak_arena_bytes {
                return Err("slab smaller than peak".to_string());
            }
            Ok(())
        },
    );
}

/// Attaching a profiler must not perturb execution: the profiled run is
/// bitwise equal to the unprofiled parallel run at every thread count
/// (the profiler only reads clocks around kernels — same tapes, same
/// per-element order), and its report samples every block of the plan.
#[test]
fn d7_profiled_runs_bitwise_equal_to_unprofiled() {
    forall(
        0xD7,
        25,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            (g, feeds)
        },
        |(g, feeds)| {
            let plan = lp_fusion(g, &FusionConfig::default());
            let prep = PreparedExec::new(g, &plan);
            let schedules = HashMap::new();
            for &threads in &THREAD_COUNTS {
                let base = execute_plan_parallel(g, &plan, feeds, &schedules, threads)
                    .map_err(|e| e.to_string())?;
                let mut prof = Profiler::new(g, &plan, threads);
                let mut sinks = OutputSink::owned(g.outputs.len());
                let (outs, _) = execute_prepared_sinks_profiled(
                    g,
                    &plan,
                    &prep,
                    &Feeds::single(feeds),
                    &schedules,
                    threads,
                    None,
                    &mut sinks,
                    Some(&prof),
                )
                .map_err(|e| e.to_string())?;
                for (i, (o, b)) in outs.iter().zip(&base).enumerate() {
                    let o = o.as_ref().ok_or_else(|| format!("output {i} missing"))?;
                    if o.data != b.data {
                        return Err(format!(
                            "output {i}: profiled({threads} threads) differs bitwise \
                             from unprofiled"
                        ));
                    }
                }
                let rep = prof.report();
                let sampled = rep.block_kinds().len();
                if sampled != plan.blocks.len() {
                    return Err(format!(
                        "profiler sampled {sampled} of {} blocks",
                        plan.blocks.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Malformed feeds are typed errors from every executor — the serving
/// layer depends on this to reject bad requests instead of dying.
#[test]
fn d6_malformed_feeds_rejected_everywhere() {
    let mut g = Graph::new();
    let a = g.input("a", &[4, 4], DType::F32);
    let b = g.weight("w", &[4]);
    let x = g.add(a, b);
    let y = g.softmax(x, 1);
    g.mark_output(y);
    let plan = lp_fusion(&g, &FusionConfig::default());

    // Missing feed.
    let mut feeds: HashMap<String, Vec<f32>> = HashMap::new();
    feeds.insert("a".to_string(), vec![0.5; 16]);
    let want = ExecError::MissingFeed { name: "w".into() };
    assert_eq!(eval_graph(&g, &feeds).unwrap_err(), want);
    assert_eq!(execute_plan(&g, &plan, &feeds, &HashMap::new()).unwrap_err(), want);
    for threads in THREAD_COUNTS {
        assert_eq!(
            execute_plan_parallel(&g, &plan, &feeds, &HashMap::new(), threads).unwrap_err(),
            want
        );
    }

    // Wrong-length feed.
    feeds.insert("w".to_string(), vec![0.5; 3]);
    let want = ExecError::FeedShape { name: "w".into(), expected: 4, got: 3 };
    assert_eq!(eval_graph(&g, &feeds).unwrap_err(), want);
    assert_eq!(execute_plan(&g, &plan, &feeds, &HashMap::new()).unwrap_err(), want);
    for threads in THREAD_COUNTS {
        assert_eq!(
            execute_plan_parallel(&g, &plan, &feeds, &HashMap::new(), threads).unwrap_err(),
            want
        );
    }

    // Fixed feeds execute fine afterwards.
    feeds.insert("w".to_string(), vec![0.5; 4]);
    let out = execute_plan_parallel(&g, &plan, &feeds, &HashMap::new(), 2).unwrap();
    assert_eq!(out[0].shape.dims, vec![4, 4]);
}

/// The persistent worker pool against the scoped spawn-per-wave
/// reference: bitwise-identical outputs at 1/2/4 workers under both
/// forced schedules, on a [64,512] fused softmax-shaped chain large
/// enough to clear the inline threshold — so the pool threads actually
/// run the waves, including the column-parallel `HoistedColMajor` path.
/// Also pins the pool's headline counter: workers are spawned at
/// construction and never again.
#[test]
fn d8_pool_matches_scoped_bitwise_all_schedules() {
    let mut g = Graph::new();
    let x = g.input("x", &[64, 512], DType::F32);
    let w = g.weight("w", &[64, 512]);
    let a = g.add(x, w);
    let t = g.add_op(Op::Tanh, &[a]);
    let r = g.add_op(Op::ReduceMax { axis: 1 }, &[t]);
    let s = g.sub(t, r);
    let e = g.add_op(Op::Exp, &[s]);
    let y = g.mul(e, a);
    g.mark_output(y);
    let mut rng = Rng::new(0xD8);
    let feeds = feeds_for(&g, &mut rng);
    let plan = lp_fusion(&g, &FusionConfig::default());
    for sched in [Schedule::RowRecompute, Schedule::HoistedColMajor] {
        let choices = force_schedule(&plan, sched);
        let seq = execute_plan(&g, &plan, &feeds, &choices).unwrap();
        for &nt in &THREAD_COUNTS {
            let scoped = execute_plan_parallel(&g, &plan, &feeds, &choices, nt).unwrap();
            let pool = WorkerPool::new(nt);
            // Several runs through the same pool: reused scratch must
            // stay bitwise-equal to the fresh-allocation reference.
            for round in 0..3 {
                let pooled = execute_plan_parallel(&g, &plan, &feeds, &choices, &pool).unwrap();
                for (i, ((p, sc), sq)) in pooled.iter().zip(&scoped).zip(&seq).enumerate() {
                    assert_eq!(
                        p.data, sc.data,
                        "{sched:?}/{nt} workers round {round}: pool differs from scoped, output {i}"
                    );
                    assert_eq!(
                        sc.data, sq.data,
                        "{sched:?}/{nt} threads: scoped differs from sequential, output {i}"
                    );
                }
            }
            let stats = pool.stats();
            assert_eq!(
                stats.spawns_total, nt as u64,
                "pool spawned threads beyond construction"
            );
        }
    }
}

/// Pruned+int8 through the pool: the fused int8 row kernels behind
/// `run_parallel_with` produce bitwise-identical logits on the pool, the
/// scoped reference, and the sequential executor (same tapes, same
/// per-element order) at every worker count.
#[test]
fn d9_pool_int8_matches_scoped_and_sequential() {
    let mut g = Graph::new();
    let x = g.input("x", &[64, 32], DType::F32);
    let w = g.weight("w", &[32, 48]);
    let b = g.weight("b", &[48]);
    let mm = g.matmul(x, w);
    let h = g.add(mm, b);
    let t = g.add_op(Op::Tanh, &[h]);
    g.mark_output(t);
    let compiled = compile(
        &g,
        &CompileOptions { compression: CompressionConfig::int8_only(), ..Default::default() },
    );
    let mut rng = Rng::new(0xD9);
    let feeds = feeds_for(&compiled.graph, &mut rng);
    let qw = compiled.quantize_weights(&feeds);
    assert!(!qw.by_node.is_empty(), "the matmul site must be quantizable");
    let layered = Feeds::single(&feeds);
    let seq = compiled.run_with(&layered, Some(&qw)).unwrap();
    for &nt in &THREAD_COUNTS {
        let (scoped, _) = compiled.run_parallel_with(&layered, nt, Some(&qw)).unwrap();
        let pool = WorkerPool::new(nt);
        let (pooled, _) = compiled.run_parallel_with(&layered, &pool, Some(&qw)).unwrap();
        for (i, ((p, sc), sq)) in pooled.iter().zip(&scoped).zip(&seq).enumerate() {
            assert_eq!(p.data, sc.data, "int8 {nt} workers: pool differs from scoped, output {i}");
            assert_eq!(
                sc.data, sq.data,
                "int8 {nt} threads: scoped differs from sequential, output {i}"
            );
        }
    }
}
