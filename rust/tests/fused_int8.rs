//! Differential coverage for the fused INT8 matmul-epilogue path — the
//! PR where compression (§2.1) and LP-Fusion (§2.2) finally compose:
//!
//! * **Fused == unfused, bitwise**: executing a `matmul -> bias [->
//!   GELU / residual]` block through the fused int8 tape kernel must be
//!   bit-identical to the per-node path (`matmul_i8` fallback + tape
//!   elementwise blocks) that a fusion-disabled compile runs.
//! * **Sequential == parallel, bitwise**: the wave executor (including
//!   its row-split of the fused kernel) agrees with the sequential plan
//!   executor at every thread count.
//! * **Close to fp32**: within the compression subsystem's documented
//!   rtol 0.1 / atol 0.05.
//! * **The bench path is fused**: the `table1_latency` pruned+int8
//!   encoder executes its weight matmuls as MatmulEpilogue blocks whose
//!   fused kernel compiles and whose weights are in the int8 table — no
//!   scratch-and-copy on that path.
//! * **The last gap is closed**: the wo/w2 projections (matmul -> bias
//!   -> residual -> layernorm) run the fused matmul+layernorm kernel in
//!   all three graphs — encoder, prefill, decode step — and the
//!   dispatch census proves the per-node int8 fallback never fires for
//!   any quantized matmul (`f7`).

use std::collections::HashMap;

use canao::compiler::codegen::tape::{compile_matmul_epilogue, compile_matmul_layernorm};
use canao::compiler::exec::Feeds;
use canao::compiler::fusion::BlockKind;
use canao::compiler::ir::{DType, Graph};
use canao::compiler::{compile, CompileOptions, Compiled};
use canao::compress::{compress_encoder, CompressionConfig};
use canao::model::{build_encoder, BertConfig};
use canao::serving::init_weights;
use canao::util::check::assert_close;
use canao::util::rng::Rng;

fn opts_int8() -> CompileOptions {
    CompileOptions {
        model_only_tuning: true,
        compression: CompressionConfig::int8_only(),
        ..Default::default()
    }
}

fn opts_int8_unfused() -> CompileOptions {
    CompileOptions {
        model_only_tuning: true,
        compression: CompressionConfig::int8_only(),
        ..CompileOptions::no_fusion()
    }
}

fn random_feeds(g: &Graph, seed: u64) -> HashMap<String, Vec<f32>> {
    use canao::compiler::ir::Op;
    let mut rng = Rng::new(seed);
    let mut feeds = HashMap::new();
    for node in &g.nodes {
        if let Op::Input { name } | Op::Weight { name } = &node.op {
            feeds.insert(
                name.clone(),
                (0..node.shape.numel()).map(|_| rng.normal_f32(0.0, 0.7)).collect(),
            );
        }
    }
    feeds
}

/// The three epilogue shapes the tentpole names: bias-only, bias+GELU,
/// and bias+residual.
fn epilogue_graph(variant: &str, m: usize, k: usize, n: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input("x", &[m, k], DType::F32);
    let w = g.weight("w", &[k, n]);
    let b = g.weight("b", &[n]);
    let mm = g.matmul(x, w);
    let biased = g.add(mm, b);
    let out = match variant {
        "bias" => biased,
        "bias+gelu" => g.gelu(biased),
        "bias+residual" => {
            let r = g.input("r", &[m, n], DType::F32);
            g.add(biased, r)
        }
        other => panic!("unknown variant {other}"),
    };
    g.mark_output(out);
    g
}

fn run_all(
    c: &Compiled,
    feeds: &HashMap<String, Vec<f32>>,
    quant: bool,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let qw = quant.then(|| c.quantize_weights(feeds));
    let f = Feeds::single(feeds);
    let seq = c.run_with(&f, qw.as_ref()).unwrap();
    let pars = [1usize, 2, 4]
        .iter()
        .map(|&t| c.run_parallel_with(&f, t, qw.as_ref()).unwrap().0[0].data.clone())
        .collect();
    (seq[0].data.clone(), pars)
}

#[test]
fn f1_fused_bitwise_equals_unfused_int8_across_epilogues() {
    for variant in ["bias", "bias+gelu", "bias+residual"] {
        let g = epilogue_graph(variant, 16, 24, 20);
        let feeds = random_feeds(&g, 0xF15E);

        let fused = compile(&g, &opts_int8());
        assert!(
            fused.plan.blocks.iter().any(|b| b.kind == BlockKind::MatmulEpilogue
                && compile_matmul_epilogue(&fused.graph, b).is_some()),
            "{variant}: no fused matmul-epilogue block"
        );
        let unfused = compile(&g, &opts_int8_unfused());

        let (fused_seq, fused_par) = run_all(&fused, &feeds, true);
        let (unfused_seq, unfused_par) = run_all(&unfused, &feeds, true);

        // Fused int8 == per-node int8 fallback, bit for bit.
        assert_eq!(fused_seq, unfused_seq, "{variant}: fused != unfused int8");
        // Sequential == parallel at every thread count, both plans.
        for (t, p) in fused_par.iter().enumerate() {
            assert_eq!(p, &fused_seq, "{variant}: fused parallel[{t}] != sequential");
        }
        for (t, p) in unfused_par.iter().enumerate() {
            assert_eq!(p, &unfused_seq, "{variant}: unfused parallel[{t}] != sequential");
        }

        // And int8 stays within the documented tolerance of fp32 — both
        // the compiled fp32 plan and the unfused reference interpreter.
        let (fp32_seq, _) = run_all(&fused, &feeds, false);
        assert_close(&fused_seq, &fp32_seq, 0.1, 0.05)
            .unwrap_or_else(|e| panic!("{variant}: int8 drifted from fp32: {e}"));
        assert_ne!(fused_seq, fp32_seq, "{variant}: int8 table silently ignored");
        let interp = canao::compiler::exec::interp::eval_graph(&g, &feeds).unwrap();
        assert_close(&fused_seq, &interp[0].data, 0.1, 0.05)
            .unwrap_or_else(|e| panic!("{variant}: fused int8 drifted from interp: {e}"));
    }
}

#[test]
fn f2_fused_kernel_row_splits_bitwise_on_tall_blocks() {
    // Tall domain (m = 256 rows) so the wave executor row-splits the
    // fused int8 kernel across threads; numerics must not move.
    let g = epilogue_graph("bias+gelu", 256, 32, 16);
    let feeds = random_feeds(&g, 0x0AB5);
    let c = compile(&g, &opts_int8());
    let (seq, pars) = run_all(&c, &feeds, true);
    for (t, p) in pars.iter().enumerate() {
        assert_eq!(p, &seq, "row-split parallel[{t}] != sequential");
    }
}

#[test]
fn f3_encoder_int8_fused_blocks_seq_eq_par() {
    let cfg = BertConfig { vocab: 64, seq: 8, layers: 2, hidden: 16, heads: 4, inter: 24 };
    let graph = build_encoder(&cfg);
    let weights = init_weights(&graph, 0xE0C0);
    let compiled = compile(&graph, &opts_int8());

    // The encoder's weight matmuls fuse with their epilogues.
    let fused_epis = compiled
        .plan
        .blocks
        .iter()
        .filter(|b| b.kind == BlockKind::MatmulEpilogue
            && compile_matmul_epilogue(&compiled.graph, b).is_some())
        .count();
    assert!(fused_epis > 0, "encoder has no fused matmul-epilogue blocks");

    let mut rng = Rng::new(7);
    let mut request = HashMap::new();
    request.insert(
        "input_ids".to_string(),
        (0..cfg.seq).map(|_| rng.below(cfg.vocab) as f32).collect::<Vec<f32>>(),
    );
    for l in 0..cfg.layers {
        request.insert(format!("mask{l}"), vec![0.0; cfg.seq]);
    }
    let qw = compiled.quantize_weights(&weights);
    let feeds = Feeds::layered(&request, &weights);

    let fp32 = compiled.run_with(&feeds, None).unwrap();
    let seq = compiled.run_with(&feeds, Some(&qw)).unwrap();
    assert_close(&seq[0].data, &fp32[0].data, 0.1, 0.05).unwrap();
    for threads in [1, 2, 4] {
        let (par, _) = compiled.run_parallel_with(&feeds, threads, Some(&qw)).unwrap();
        assert_eq!(par[0].data, seq[0].data, "int8 parallel != sequential at {threads}");
    }

    // Slab pooling: the serial run_parallel_with calls above each checked
    // a slab out and returned it, so exactly one is parked — and another
    // parallel request recycles it rather than allocating a second.
    // (The sequential executor `run_with` never touches the pool.)
    assert_eq!(compiled.prepared().pooled_slabs(), 1);
    let _ = compiled.run_parallel_with(&feeds, 2, Some(&qw)).unwrap();
    assert_eq!(compiled.prepared().pooled_slabs(), 1);
}

/// Pins the acceptance criterion: the `table1_latency` pruned+int8 row's
/// model executes its weight matmuls (incl. matmul+bias+GELU in the FFN)
/// as fused MatmulEpilogue tape blocks whose weights are all in the int8
/// table — the path with no scratch tensor and no copy.
#[test]
fn f4_table1_pruned_int8_row_runs_fused() {
    let cfg = BertConfig { vocab: 2048, seq: 64, layers: 2, hidden: 128, heads: 4, inter: 512 };
    let comp = CompressionConfig::pruned_int8(0.5, 0.5);
    let dense = build_encoder(&cfg);
    let mut weights = init_weights(&dense, 0xC0DE);
    let (graph, _report) = compress_encoder(&cfg, &mut weights, &comp);
    let compiled = compile(
        &graph,
        &CompileOptions { model_only_tuning: true, compression: comp, ..Default::default() },
    );
    let (qw, summary) = compiled.quantize_weights_report(&weights);
    assert!(summary.all_quantized(), "bench weights must fully quantize: {summary}");

    let mut fused = 0usize;
    let mut gelu_fused = 0usize;
    for block in &compiled.plan.blocks {
        let Some(mt) = compile_matmul_epilogue(&compiled.graph, block) else { continue };
        assert!(
            qw.by_node.contains_key(&mt.rhs),
            "fused epilogue weight missing from the int8 table"
        );
        fused += 1;
        // The FFN's matmul+bias+GELU epilogue contains the erf.
        if mt.tape.insts.iter().any(|i| {
            matches!(
                i,
                canao::compiler::codegen::tape::TapeInst::Unary {
                    op: canao::compiler::codegen::tape::UOp::Erf,
                    ..
                }
            )
        }) {
            gelu_fused += 1;
        }
    }
    // Per layer at least: Q/K/V projections (bias-only) + the FFN's
    // matmul+bias+GELU.
    assert!(fused >= 4 * cfg.layers, "only {fused} fused epilogue blocks");
    assert!(
        gelu_fused >= cfg.layers,
        "matmul+bias+GELU must run as one fused tape block per layer (got {gelu_fused})"
    );
    // And the wo/w2 matmuls — merged with their downstream layernorms —
    // run the fused matmul+layernorm kernel, closing the last per-node
    // int8 fallback.
    let mut ln_fused = 0usize;
    for block in &compiled.plan.blocks {
        let Some(mt) = compile_matmul_layernorm(&compiled.graph, block) else { continue };
        assert!(
            qw.by_node.contains_key(&mt.rhs),
            "fused layernorm weight missing from the int8 table"
        );
        ln_fused += 1;
    }
    assert_eq!(
        ln_fused,
        2 * cfg.layers,
        "wo + w2 must each run the fused matmul+layernorm kernel per layer"
    );
}

/// The tentpole's differential: a matmul -> bias -> residual ->
/// layernorm graph runs the fused matmul+layernorm kernel, bitwise equal
/// to the per-node path of a fusion-disabled compile — int8 AND fp32 —
/// sequential == parallel at 1/2/4 threads including the row-split, and
/// int8 within the documented tolerance of fp32.
#[test]
fn f5_fused_matmul_layernorm_bitwise_equals_unfused() {
    // m = 256 rows so the wave executor row-splits the fused kernel.
    for (m, k, n) in [(16, 24, 20), (256, 32, 16)] {
        let mut g = Graph::new();
        let x = g.input("x", &[m, k], DType::F32);
        let r = g.input("r", &[m, n], DType::F32);
        let w = g.weight("w", &[k, n]);
        let b = g.weight("b", &[n]);
        let ga = g.weight("gamma", &[n]);
        let be = g.weight("beta", &[n]);
        let mm = g.matmul(x, w);
        let biased = g.add(mm, b);
        let res = g.add(biased, r);
        let ln = g.layernorm(res, ga, be, 1e-12);
        g.mark_output(ln);
        let feeds = random_feeds(&g, 0x11AA ^ m as u64);

        let fused = compile(&g, &opts_int8());
        assert!(
            fused
                .plan
                .blocks
                .iter()
                .any(|bl| compile_matmul_layernorm(&fused.graph, bl).is_some()),
            "no fused matmul+layernorm block at m={m}"
        );
        let unfused = compile(&g, &opts_int8_unfused());

        let (fused_seq, fused_par) = run_all(&fused, &feeds, true);
        let (unfused_seq, unfused_par) = run_all(&unfused, &feeds, true);
        assert_eq!(fused_seq, unfused_seq, "m={m}: fused != unfused int8");
        for (t, p) in fused_par.iter().enumerate() {
            assert_eq!(p, &fused_seq, "m={m}: fused parallel[{t}] != sequential");
        }
        for (t, p) in unfused_par.iter().enumerate() {
            assert_eq!(p, &unfused_seq, "m={m}: unfused parallel[{t}] != sequential");
        }

        // fp32: the fused kernel must also be bitwise-identical to the
        // per-node fp32 path (interp-mirroring matmul + shared
        // layernorm arithmetic) — and int8 within tolerance of it.
        let (fp32_fused, fp32_par) = run_all(&fused, &feeds, false);
        let (fp32_unfused, _) = run_all(&unfused, &feeds, false);
        assert_eq!(fp32_fused, fp32_unfused, "m={m}: fused fp32 != per-node fp32");
        for (t, p) in fp32_par.iter().enumerate() {
            assert_eq!(p, &fp32_fused, "m={m}: fp32 parallel[{t}] != sequential");
        }
        assert_close(&fused_seq, &fp32_fused, 0.1, 0.05)
            .unwrap_or_else(|e| panic!("m={m}: int8 drifted from fp32: {e}"));
        assert_ne!(fused_seq, fp32_fused, "m={m}: int8 table silently ignored");
        let interp = canao::compiler::exec::interp::eval_graph(&g, &feeds).unwrap();
        assert_eq!(fp32_fused, interp[0].data, "m={m}: fused fp32 != interp");
    }
}

/// Acceptance criterion: the pruned+int8 encoder, prefill, and
/// decode-step graphs execute with ZERO per-node int8 matmul fallbacks.
/// Every quantized matmul runs a fused kernel — MatmulEpilogue for
/// Q/K/V/w1, MatmulLayernorm for wo/w2 — except the prefill/step LM
/// head, a single-op matmul block with nothing to fuse (direct int8
/// dispatch straight into its arena region, not the
/// scratch-compute-then-rescale fallback shape).
#[test]
fn f7_no_per_node_int8_fallback_in_any_graph() {
    use canao::serving::NativeGenEngine;
    use canao::tokenizer::{Tokenizer, Vocab};
    use std::sync::Arc;

    let comp = CompressionConfig::pruned_int8(0.5, 0.5);

    // Encoder.
    let cfg = BertConfig { vocab: 2048, seq: 64, layers: 2, hidden: 128, heads: 4, inter: 512 };
    let dense = build_encoder(&cfg);
    let mut weights = init_weights(&dense, 0xC0DE);
    let (graph, _report) = compress_encoder(&cfg, &mut weights, &comp);
    let compiled = compile(
        &graph,
        &CompileOptions { model_only_tuning: true, compression: comp, ..Default::default() },
    );
    let qw = compiled.quantize_weights(&weights);
    let enc = compiled.dispatch_counts(Some(&qw));
    assert_eq!(enc.fallback_i8_matmul, 0, "encoder: {enc}");
    assert_eq!(enc.direct_i8_matmul, 0, "encoder has no lone weight matmul: {enc}");
    assert_eq!(enc.fused_layernorm_i8, 2 * cfg.layers, "encoder wo/w2: {enc}");
    assert!(enc.fused_epilogue_i8 >= 4 * cfg.layers, "encoder q/k/v/w1: {enc}");

    // Prefill + decode step (the textgen engine's two graphs).
    let corpus = "the quick brown fox jumps over the lazy dog .";
    let tok = Arc::new(Tokenizer::new(Vocab::build(corpus, 256)));
    let gcfg = BertConfig { vocab: 256, seq: 16, layers: 2, hidden: 16, heads: 2, inter: 32 };
    let engine = NativeGenEngine::with_compression(tok, gcfg, 2, comp);
    let (pc, sc) = engine.decoder().dispatch_counts();
    for (label, c) in [("prefill", pc), ("step", sc)] {
        assert_eq!(c.fallback_i8_matmul, 0, "{label}: {c}");
        assert_eq!(c.fused_layernorm_i8, 2 * gcfg.layers, "{label} wo/w2: {c}");
        assert!(c.fused_epilogue_i8 >= 4 * gcfg.layers, "{label} q/k/v/w1: {c}");
        assert_eq!(c.direct_i8_matmul, 1, "{label} LM head: {c}");
    }
}
