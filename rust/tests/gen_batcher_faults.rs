//! Continuous-batching scheduler faults: every failure a generation
//! caller can hit must surface as a typed `GenBatcherError` — never a
//! hang, never a propagated panic — and a per-session failure must never
//! take down sessions that are already generating. Mirrors
//! `tests/batcher_faults.rs` for the `GenBatcher` scheduler.

use std::sync::Arc;
use std::time::{Duration, Instant};

use canao::model::BertConfig;
use canao::serving::{
    GenBatcher, GenBatcherError, GenBatcherOptions, GenRequest, NativeGenEngine,
};
use canao::tokenizer::{Tokenizer, Vocab};

const CORPUS: &str = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word . \
                      layer fusion reduces the number of kernels .";

/// Engine weights are drawn from a fixed seed, so two engines built from
/// the same config are identical — the batch-1 reference and the batched
/// scheduler can be compared across separate instances.
fn tiny_gen(threads: usize) -> NativeGenEngine {
    let tok = Arc::new(Tokenizer::new(Vocab::build(CORPUS, 256)));
    let cfg = BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
    NativeGenEngine::new(tok, cfg, threads)
}

fn req(prompt: &str, max_new_tokens: usize, seed: u64) -> GenRequest {
    GenRequest { prompt: prompt.into(), max_new_tokens, temperature: 0.9, seed }
}

/// Submit with a bounded retry: the worker releases a retiring session's
/// slot reservation moments after sending its reply, so a submit racing
/// that window may see `SlotsFull` briefly even though a slot is about
/// to free up.
fn submit_eventually(
    gb: &GenBatcher,
    r: GenRequest,
) -> std::sync::mpsc::Receiver<Result<canao::serving::GenResponse, GenBatcherError>> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match gb.submit(r.clone()) {
            Ok(rx) => return rx,
            Err(GenBatcherError::SlotsFull { .. }) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => panic!("submit failed: {e}"),
        }
    }
}

#[test]
fn staggered_retirement_matches_batch1_text_per_session() {
    // Four sessions with different token budgets: they retire mid-batch
    // at different waves while the others keep stepping, and every one
    // must produce exactly the text the batch-1 engine generates for the
    // same request (same seed, same sampling) — the end-to-end form of
    // the bitwise step contract.
    let reqs: Vec<GenRequest> = [("the model", 2u64), ("the quick brown", 3), ("fox", 4), ("lazy dog", 5)]
        .iter()
        .enumerate()
        .map(|(i, &(p, s))| req(p, 2 + i * 2, 100 + s))
        .collect();
    let reference: Vec<_> = {
        let eng = tiny_gen(2);
        reqs.iter().map(|r| eng.generate(r).expect("batch-1 reference")).collect()
    };

    let gb = GenBatcher::new(tiny_gen(2), GenBatcherOptions { max_slots: 4, ..Default::default() });
    let rxs: Vec<_> = reqs.iter().map(|r| gb.submit(r.clone()).expect("4 slots free")).collect();
    for (i, (rx, want)) in rxs.into_iter().zip(&reference).enumerate() {
        let got = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("no caller hangs")
            .expect("session succeeds");
        assert_eq!(got.text, want.text, "session {i} text != batch-1");
        assert_eq!(got.tokens_generated, want.tokens_generated, "session {i}");
        assert_eq!(got.per_token_ms.len(), want.per_token_ms.len(), "session {i}");
    }
    assert_eq!(gb.metrics.completed.get(), 4);
    assert_eq!(gb.metrics.failed.get(), 0);
    assert!(gb.metrics.steps.get() > 0, "waves were dispatched");
    assert!(gb.metrics.peak_occupancy() >= 1);
    gb.shutdown();
}

#[test]
fn slots_full_rejects_typed_and_frees_on_retirement() {
    let gb = GenBatcher::new(tiny_gen(1), GenBatcherOptions { max_slots: 1, ..Default::default() });
    // Occupy the only slot with a long-ish session.
    let rx = gb.submit(req("the model generates", 8, 1)).expect("slot free");
    assert_eq!(gb.slots_in_use(), 1);

    // The next admission is refused immediately, typed.
    match gb.submit(req("fox", 2, 2)) {
        Err(GenBatcherError::SlotsFull { slots }) => assert_eq!(slots, 1),
        other => panic!("expected SlotsFull, got {other:?}"),
    }
    assert_eq!(gb.metrics.rejected.get(), 1);

    // The occupant completes and its slot frees for new work.
    assert!(rx.recv_timeout(Duration::from_secs(10)).expect("no hang").is_ok());
    let rx2 = submit_eventually(&gb, req("fox", 2, 2));
    assert!(rx2.recv_timeout(Duration::from_secs(10)).expect("no hang").is_ok());
    gb.shutdown();
}

#[test]
fn page_pool_exhaustion_fails_the_session_not_the_batch() {
    // 1 layer -> 2 pages per session; a 4-page cap seats exactly two
    // concurrent sessions. Admissions three and four must fail typed
    // while the seated sessions run to completion unharmed.
    let gb = GenBatcher::new(
        tiny_gen(1),
        GenBatcherOptions { max_slots: 4, max_kv_pages: Some(4), ..Default::default() },
    );
    let rxs: Vec<_> = (0..4)
        .map(|i| gb.submit(req("the model generates", 9, i as u64)).expect("slots free"))
        .collect();

    let mut ok = 0;
    let mut exhausted = 0;
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(10)).expect("no caller hangs") {
            Ok(resp) => {
                assert!(resp.tokens_generated > 0);
                ok += 1;
            }
            Err(GenBatcherError::PagePoolExhausted { in_use, capacity }) => {
                assert_eq!(capacity, 4);
                assert_eq!(in_use, 4, "both seated sessions hold their pages");
                exhausted += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(ok, 2, "seated sessions complete");
    assert_eq!(exhausted, 2, "unseatable sessions fail typed");
    assert_eq!(gb.metrics.failed.get(), 2);
    assert_eq!(gb.metrics.completed.get(), 2);
    let pool = gb.metrics.kv_pages.get();
    assert_eq!(pool.capacity, Some(4));
    assert!(pool.peak_in_use <= 4, "cap was honored: {pool:?}");

    // Pages returned at retirement: the pool recovers and new sessions
    // seat again — exhaustion is a per-session admission failure, not a
    // poisoned scheduler.
    let rx = submit_eventually(&gb, req("fox", 2, 9));
    assert!(rx.recv_timeout(Duration::from_secs(10)).expect("no hang").is_ok());
    gb.shutdown();
}

#[test]
fn dropped_receivers_do_not_wedge_the_scheduler() {
    let gb = GenBatcher::new(tiny_gen(1), GenBatcherOptions { max_slots: 2, ..Default::default() });
    // Submit and immediately drop the receivers while the sessions are
    // in flight: the worker's reply sends fail silently and retirement
    // still frees the slots and pages.
    for i in 0..6u64 {
        drop(submit_eventually(&gb, req("the model", 3, i)));
    }
    // The scheduler is still alive and serving; the reply matches the
    // batch-1 engine as usual.
    let want = tiny_gen(1).generate(&req("lazy dog", 2, 42)).unwrap();
    let rx = submit_eventually(&gb, req("lazy dog", 2, 42));
    let got = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("scheduler not wedged")
        .expect("session succeeds");
    assert_eq!(got.text, want.text);
    // Dropping the batcher with nothing in flight joins cleanly.
    gb.shutdown();
}

#[test]
fn zero_budget_and_oversized_prompts_behave_like_batch1() {
    let gb = GenBatcher::new(tiny_gen(1), GenBatcherOptions::default());
    let eng = tiny_gen(1);

    // max_new_tokens = 0: no forward at all, prompt echoed back.
    let zero = req("the model", 0, 1);
    let want = eng.generate(&zero).unwrap();
    let got = gb.call(zero).expect("zero-budget session succeeds");
    assert_eq!(got.text, want.text);
    assert_eq!(got.tokens_generated, 0);

    // A prompt tokenizing past seq truncates deterministically and still
    // generates, identically to batch-1.
    let long = req(CORPUS, 5, 2);
    let want = eng.generate(&long).unwrap();
    let got = gb.call(long).expect("truncated session succeeds");
    assert_eq!(got.text, want.text);
    assert_eq!(got.tokens_generated, want.tokens_generated);
    gb.shutdown();
}
