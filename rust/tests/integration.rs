//! Cross-module integration tests that don't need PJRT artifacts:
//! NAS over the real compiler + device simulator, reports, batcher + mock
//! model, and CLI-level wiring.

use std::time::Duration;

use canao::device::DeviceProfile;
use canao::nas::{Search, SearchConfig};
use canao::serving::batcher::{BatchModel, Batcher, BatcherOptions};
use canao::table1_rows;

/// The full compiler-in-the-loop NAS produces an architecture meeting the
/// latency target when one exists, and its latency ordering is consistent
/// with the device simulator.
#[test]
fn nas_finds_latency_feasible_architecture() {
    let mut s = Search::new(SearchConfig {
        device: DeviceProfile::s865_cpu(),
        target_ms: 120.0,
        lambda: 2.0,
        phase1_iters: 5,
        phase2_iters: 8,
        batch: 6,
        seed: 99,
        ..Default::default()
    });
    let res = s.run();
    assert!(
        res.best.latency_ms < 180.0,
        "best {:?} at {:.0}ms",
        res.best.cfg,
        res.best.latency_ms
    );
    assert!(res.best.accuracy > 60.0);
    // The search must have actually explored (several unique configs).
    assert!(res.evaluations >= 5, "{}", res.evaluations);
}

/// Ablation D3: dropping the latency term lets the search drift to bigger
/// models — the paper's motivation for compiler-aware search.
#[test]
fn ablation_accuracy_only_prefers_bigger_models() {
    let base = SearchConfig {
        target_ms: 30.0,
        lambda: 4.0,
        phase1_iters: 6,
        phase2_iters: 10,
        batch: 6,
        seed: 5,
        ..Default::default()
    };
    let with_lat = Search::new(base.clone()).run();
    let acc_only = Search::new(SearchConfig { accuracy_only: true, ..base }).run();
    assert!(
        acc_only.best.cfg.flops() >= with_lat.best.cfg.flops(),
        "acc-only {:?} vs constrained {:?}",
        acc_only.best.cfg,
        with_lat.best.cfg
    );
    assert!(acc_only.best.accuracy >= with_lat.best.accuracy - 0.5);
}

/// Ablation D1: taking fusion OUT of the latency estimate inflates every
/// candidate's latency, shifting the reward landscape.
#[test]
fn ablation_fusion_in_loop_changes_latency_estimates() {
    let mk = |no_fusion| {
        SearchConfig {
            no_fusion_in_loop: no_fusion,
            phase1_iters: 1,
            phase2_iters: 1,
            batch: 2,
            ..Default::default()
        }
    };
    let cfg = canao::model::BertConfig::canaobert();
    let mut with = Search::new(mk(false));
    let mut without = Search::new(mk(true));
    let l_with = with.latency_ms(&cfg);
    let l_without = without.latency_ms(&cfg);
    assert!(
        l_without > 1.3 * l_with,
        "unfused-in-loop {l_without:.0}ms vs fused {l_with:.0}ms"
    );
}

/// Table 1 rows are internally consistent: FLOPs ordering matches latency
/// ordering per column.
#[test]
fn table1_rows_consistent() {
    let rows = table1_rows();
    assert_eq!(rows.len(), 3);
    let by = |f: fn(&canao::reports::Table1Row) -> f64| {
        let mut v: Vec<(String, f64)> =
            rows.iter().map(|r| (r.name.to_string(), f(r))).collect();
        v.sort_by(|a, b| a.1.total_cmp(&b.1));
        v.into_iter().map(|(n, _)| n).collect::<Vec<_>>()
    };
    let flops_order = by(|r| r.gflops);
    assert_eq!(flops_order, by(|r| r.tflite_cpu_ms));
    assert_eq!(flops_order, by(|r| r.fuse_cpu_ms));
    assert_eq!(flops_order, by(|r| r.fuse_gpu_ms));
}

/// Batcher under sustained offered load keeps batching efficiency high.
#[test]
fn batcher_sustains_throughput() {
    struct SlowEcho;
    impl BatchModel<u64, u64> for SlowEcho {
        fn max_batch(&self) -> usize {
            8
        }
        fn run_batch(&self, items: &[u64]) -> Vec<u64> {
            // Fixed per-batch cost: batching amortizes it.
            std::thread::sleep(Duration::from_millis(2));
            items.to_vec()
        }
    }
    let b = std::sync::Arc::new(Batcher::new(
        SlowEcho,
        BatcherOptions { max_wait: Duration::from_millis(3), min_batch: 4, queue_cap: 256 },
    ));
    let n = 64;
    let start = std::time::Instant::now();
    let rxs: Vec<_> = (0..n).map(|i| b.submit(i).expect("cap 256 queue admits 64 jobs")).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        assert_eq!(rx.recv().unwrap(), Ok(i as u64));
    }
    let elapsed = start.elapsed();
    let m = &b.metrics;
    // 64 sequential 2ms calls would take 128ms+; batching must beat 64ms.
    assert!(elapsed < Duration::from_millis(64), "{elapsed:?}");
    assert!(m.mean_batch_size() > 2.0, "{}", m.mean_batch_size());
}

/// JSON substrate handles the real manifest format end to end.
#[test]
fn manifest_roundtrip_through_json_substrate() {
    use canao::util::json::Json;
    let j = Json::parse(
        r#"{"version":1,"models":{},"executables":{}}"#,
    )
    .unwrap();
    assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
    let dumped = j.dump();
    assert_eq!(Json::parse(&dumped).unwrap(), j);
}
