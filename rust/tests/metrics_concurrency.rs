//! Metrics-under-concurrency pins (PR 9): the lock-free serving metrics
//! stay accurate when many threads record at once.
//!
//! * `StreamingHistogram` under >= 4 concurrent recorders: no lost
//!   samples, sums exact, percentiles inside the documented <= 1/8
//!   relative-error band;
//! * `merge_from` is equivalent to recording directly into one
//!   histogram (the cross-thread aggregation path);
//! * `GenBatcherMetrics` under concurrent submitters through a real
//!   2-slot scheduler: counters reconcile exactly with what the callers
//!   observed — no drops, no double counts.

use std::sync::Arc;
use std::time::Duration;

use canao::model::BertConfig;
use canao::serving::{
    GenBatcher, GenBatcherError, GenBatcherOptions, GenRequest, NativeGenEngine, StreamingHistogram,
};
use canao::tokenizer::{Tokenizer, Vocab};

const CORPUS: &str = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word .";

fn tiny_gen(threads: usize) -> NativeGenEngine {
    let tok = Arc::new(Tokenizer::new(Vocab::build(CORPUS, 256)));
    let cfg = BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
    NativeGenEngine::new(tok, cfg, threads)
}

#[test]
fn histogram_is_accurate_under_concurrent_recording() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    let h = StreamingHistogram::new();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let h = &h;
            s.spawn(move || {
                // Each thread covers the full 1..=1000 range so every
                // bucket sees contention from every thread.
                for i in 0..PER_THREAD {
                    h.record_value(1 + (t + i * THREADS) % 1000);
                }
            });
        }
    });
    assert_eq!(h.len(), THREADS * PER_THREAD, "no lost samples under contention");
    // Exact sum: each thread records every residue of 1..=1000 exactly
    // PER_THREAD/1000 times, so the total is THREADS * 10 * (1+..+1000).
    assert_eq!(h.sum(), THREADS * (PER_THREAD / 1000) * (1000 * 1001 / 2));
    // Percentiles report bucket midpoints: <= 1/8 relative error.
    let p50 = h.percentile_value(50.0);
    assert!((400..=650).contains(&p50), "p50 of uniform 1..=1000 was {p50}");
    assert!(h.max_value() >= 875, "max bucket midpoint for 1000 was {}", h.max_value());
    let mean = h.mean_value();
    assert!((mean - 500.5).abs() < 500.5 / 8.0, "mean of uniform 1..=1000 was {mean}");
}

#[test]
fn merge_matches_direct_recording() {
    let direct = StreamingHistogram::new();
    let merged = StreamingHistogram::new();
    let shards: Vec<StreamingHistogram> =
        (0..4).map(|_| StreamingHistogram::new()).collect();
    std::thread::scope(|s| {
        for (k, shard) in shards.iter().enumerate() {
            s.spawn(move || {
                for i in 0..5_000u64 {
                    // A skewed mix: mostly small values, a heavy tail.
                    let v = if i % 97 == 0 { 50_000 + k as u64 } else { 1 + i % 300 };
                    shard.record_value(v);
                }
            });
        }
    });
    for shard in &shards {
        merged.merge_from(shard);
    }
    // Replay the same values into one histogram directly.
    for k in 0..4u64 {
        for i in 0..5_000u64 {
            let v = if i % 97 == 0 { 50_000 + k } else { 1 + i % 300 };
            direct.record_value(v);
        }
    }
    assert_eq!(merged.len(), direct.len());
    assert_eq!(merged.sum(), direct.sum());
    assert_eq!(merged.max_value(), direct.max_value());
    for p in [50.0, 95.0, 99.0] {
        assert_eq!(
            merged.percentile_value(p),
            direct.percentile_value(p),
            "p{p} differs between merged shards and direct recording"
        );
    }
}

#[test]
fn gen_batcher_metrics_reconcile_under_concurrent_submitters() {
    let gb = Arc::new(GenBatcher::new(
        tiny_gen(2),
        GenBatcherOptions { max_slots: 2, ..Default::default() },
    ));
    let (done, rejected) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let gb = Arc::clone(&gb);
                s.spawn(move || {
                    let mut done = 0u64;
                    let mut rejected = 0u64;
                    for i in 0..6u64 {
                        let req = GenRequest {
                            prompt: "the model".to_string(),
                            max_new_tokens: 2,
                            temperature: 0.9,
                            seed: t * 100 + i,
                        };
                        // Admission control may shed under contention;
                        // every shed must be the typed SlotsFull error,
                        // and the counters must see exactly one outcome
                        // per submission.
                        match gb.call(req) {
                            Ok(resp) => {
                                assert!(resp.tokens_generated > 0);
                                done += 1;
                            }
                            Err(GenBatcherError::SlotsFull { slots }) => {
                                assert_eq!(slots, 2);
                                rejected += 1;
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(e) => panic!("unexpected scheduler error: {e:?}"),
                        }
                    }
                    (done, rejected)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("submitter thread")).fold(
            (0u64, 0u64),
            |(d, r), (dd, rr)| (d + dd, r + rr),
        )
    });
    let metrics = Arc::clone(&gb.metrics);
    // Drop joins the worker: every in-flight retirement lands in the
    // metrics before the snapshot below.
    drop(Arc::try_unwrap(gb).expect("all submitter clones joined"));

    assert!(done > 0, "at least some sessions complete");
    assert_eq!(metrics.completed.get(), done, "completions reconcile with callers");
    assert_eq!(metrics.rejected.get(), rejected, "rejects reconcile with callers");
    assert_eq!(metrics.requests.get(), done, "`requests` counts accepted admissions");
    assert_eq!(metrics.failed.get(), 0);
    assert!(metrics.steps.get() > 0);
    let occ = metrics.mean_occupancy();
    assert!((1.0..=2.0).contains(&occ), "mean occupancy {occ} outside [1, slots]");
    assert!(metrics.peak_occupancy() <= 2);
    assert_eq!(metrics.active_sessions.get(), 0, "all sessions retired");
}
