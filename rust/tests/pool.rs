//! Integration pins for the persistent worker-pool runtime
//! (`compiler/exec/pool.rs`): panic containment through the public API,
//! clean thread join on `Drop`, and the headline steady-state decode
//! contract — zero thread spawns and zero kernel-scratch growth per
//! generated token once the pool and its per-worker arenas are warm.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use canao::compiler::exec::WorkerPool;
use canao::compress::CompressionConfig;
use canao::model::BertConfig;
use canao::serving::NativeGenEngine;
use canao::tokenizer::{Tokenizer, Vocab};

fn demo_engine(comp: CompressionConfig) -> NativeGenEngine {
    let corpus = "the quick brown fox jumps over the lazy dog . \
                  the model generates new sentences word by word .";
    let tok = Arc::new(Tokenizer::new(Vocab::build(corpus, 512)));
    let cfg = BertConfig { vocab: 512, seq: 48, layers: 2, hidden: 64, heads: 4, inter: 256 };
    NativeGenEngine::with_compression(tok, cfg, 2, comp)
}

/// A panicking task poisons neither the pool nor its threads: the run
/// reports the failure, the SAME workers serve the next wave, and the
/// spawn counter proves no replacement thread was created.
#[test]
fn panicking_task_is_contained_and_pool_stays_usable() {
    let pool = WorkerPool::new(2);
    let r = pool.run(2, &|w, _scratch| {
        if w == 1 {
            panic!("injected worker failure");
        }
    });
    assert!(r.is_err(), "worker panic must surface as PoolPanicked");

    let ran = AtomicUsize::new(0);
    pool.run(2, &|_, _| {
        ran.fetch_add(1, Ordering::SeqCst);
    })
    .expect("pool serves waves after a contained panic");
    assert_eq!(ran.load(Ordering::SeqCst), 2, "both workers ran the recovery wave");
    assert_eq!(
        pool.stats().spawns_total,
        2,
        "containment must not respawn threads"
    );
}

/// `Drop` joins every worker: the exit counter (incremented by each
/// worker on its way out) reaches the pool size by the time `drop`
/// returns — no detached threads outlive the pool.
#[test]
fn drop_joins_every_worker_thread() {
    let pool = WorkerPool::new(4);
    let exits = pool.exits_handle();
    assert_eq!(exits.load(Ordering::SeqCst), 0, "workers alive while pool is");
    drop(pool);
    assert_eq!(exits.load(Ordering::SeqCst), 4, "drop returned before all workers exited");
}

/// The steady-state decode contract from the pool refactor: once a
/// session is warm, generating further tokens spawns no threads and
/// grows no kernel scratch — every step runs on parked pool workers and
/// reused arenas. Covers fp32 and pruned+int8.
#[test]
fn steady_state_decode_spawns_nothing_and_grows_no_scratch() {
    for comp in [CompressionConfig::none(), CompressionConfig::pruned_int8(0.5, 0.5)] {
        let pool = WorkerPool::new(2);
        let engine = demo_engine(comp);
        let dec = engine.decoder();
        let prompt: Vec<i32> = (2..10).collect();

        let mut sess = dec.begin(engine.weights(), &pool);
        sess.prefill(&prompt).expect("prefill");
        // Warm-up: the first steps may grow the step plan's scratch
        // arenas to their high-water marks.
        for t in 0..3 {
            sess.step(2 + t).expect("warm-up step");
        }

        let before = pool.stats();
        for t in 0..8 {
            sess.step(3 + t).expect("steady-state step");
            let stats = sess.last_stats().expect("parallel run records stats");
            assert_eq!(
                stats.scratch_grows, 0,
                "int8={}: steady-state step grew kernel scratch",
                comp.int8
            );
        }
        let after = pool.stats();
        assert_eq!(
            after.spawns_total, before.spawns_total,
            "int8={}: steady-state decode spawned threads",
            comp.int8
        );
        assert_eq!(
            after.scratch_grows, before.scratch_grows,
            "int8={}: steady-state decode grew pool worker scratch",
            comp.int8
        );
        sess.finish();
    }
}
