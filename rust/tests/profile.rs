//! Integration tests for the execution profiler's export views and the
//! measured-vs-predicted device-model calibration:
//!
//! * the chrome://tracing export round-trips through the in-tree JSON
//!   parser with the trace_event schema intact;
//! * the per-kernel-kind aggregate accounts for every recorded dispatch
//!   and its totals sum exactly;
//! * calibrating a tiny BERT encoder yields a structurally sound report
//!   (positive predictions, finite errors, fitted rates inside the
//!   clamp band) without asserting tight timing bounds — CI hosts are
//!   noisy, so these are invariants, not benchmarks.

use std::collections::HashMap;

use canao::compiler::{compile, CompileOptions, Compiled};
use canao::device::calibration::{calibrate_runs, profile_runs};
use canao::device::DeviceProfile;
use canao::model::{build_encoder, BertConfig};
use canao::util::json::Json;

/// A 1-layer encoder small enough that a profiled run is milliseconds.
fn tiny_bert() -> (Compiled, HashMap<String, Vec<f32>>) {
    let cfg = BertConfig { vocab: 64, seq: 8, layers: 1, hidden: 16, heads: 2, inter: 32 };
    let g = build_encoder(&cfg);
    let c = compile(&g, &CompileOptions { model_only_tuning: true, ..Default::default() });
    let mut feeds = canao::serving::init_weights(&g, 0xBEEF);
    feeds.insert("input_ids".to_string(), (0..cfg.seq).map(|i| (i % 60) as f32).collect());
    for l in 0..cfg.layers {
        feeds.insert(format!("mask{l}"), vec![0.0; cfg.seq]);
    }
    (c, feeds)
}

#[test]
fn trace_json_round_trips() {
    let (c, feeds) = tiny_bert();
    let reps = profile_runs(&c, &feeds, None, 2, 1).unwrap();
    let rep = &reps[0];
    assert!(!rep.blocks.is_empty(), "profiled run recorded no dispatches");
    let parsed = Json::parse(&rep.chrome_trace().dump()).expect("trace must be valid JSON");
    assert_eq!(parsed.get("displayTimeUnit").and_then(|d| d.as_str()), Some("ns"));
    let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    // One complete event per block dispatch plus one per wave.
    assert_eq!(events.len(), rep.blocks.len() + rep.waves.len());
    for ev in events {
        assert_eq!(ev.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert!(ev.get("name").and_then(|n| n.as_str()).is_some());
        assert!(ev.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(ev.get("dur").and_then(|d| d.as_f64()).is_some());
        assert!(ev.get("pid").and_then(|p| p.as_f64()).is_some());
        assert!(ev.get("tid").and_then(|t| t.as_f64()).is_some());
        assert!(ev.get("args").is_some());
    }
    // Kernel events sit on real thread lanes; wave events on lane 99.
    let lanes: Vec<f64> =
        events.iter().filter_map(|e| e.get("tid").and_then(|t| t.as_f64())).collect();
    assert!(lanes.iter().any(|&t| t < 99.0), "no kernel lanes in trace");
    assert_eq!(lanes.iter().filter(|&&t| t == 99.0).count(), rep.waves.len());
}

#[test]
fn chrome_trace_with_appends_extra_lanes() {
    // PR 9: request lanes from the serving tracer merge into the kernel
    // timeline through `chrome_trace_with` — extra events are appended
    // verbatim after the kernel/wave events, and the plain export stays
    // pinned to blocks + waves.
    let (c, feeds) = tiny_bert();
    let rep = profile_runs(&c, &feeds, None, 2, 1).unwrap().remove(0);
    let extra: Vec<Json> = (0..2)
        .map(|i| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("name".to_string(), Json::Str(format!("request {i}")));
            m.insert("ph".to_string(), Json::Str("X".to_string()));
            m.insert("ts".to_string(), Json::Num(0.0));
            m.insert("dur".to_string(), Json::Num(1.0));
            m.insert("pid".to_string(), Json::Num(0.0));
            m.insert("tid".to_string(), Json::Num((100 + i) as f64));
            Json::Obj(m)
        })
        .collect();
    let merged = Json::parse(&rep.chrome_trace_with(&extra).dump()).unwrap();
    let events = merged.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    assert_eq!(events.len(), rep.blocks.len() + rep.waves.len() + extra.len());
    let request_lanes =
        events.iter().filter(|e| e.get("tid").and_then(|t| t.as_f64()) >= Some(100.0)).count();
    assert_eq!(request_lanes, extra.len(), "request lanes survive the merge");
    // The no-extra form is the delegating identity.
    let plain = Json::parse(&rep.chrome_trace().dump()).unwrap();
    let plain_events = plain.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    assert_eq!(plain_events.len(), rep.blocks.len() + rep.waves.len());
}

#[test]
fn aggregate_accounts_for_every_dispatch() {
    let (c, feeds) = tiny_bert();
    let rep = profile_runs(&c, &feeds, None, 4, 1).unwrap().remove(0);
    assert!(!rep.blocks.is_empty());
    let agg = rep.aggregate();
    let sample_sum: u64 = rep.blocks.iter().map(|s| s.dur_ns).sum();
    let kind_sum: u64 = agg.kinds.iter().map(|k| k.total_ns).sum();
    assert_eq!(kind_sum, agg.total_ns, "per-kind totals must sum to the aggregate total");
    assert_eq!(agg.total_ns, sample_sum, "aggregate total must equal the sample sum");
    let counted: usize = agg.kinds.iter().map(|k| k.count).sum();
    assert_eq!(counted, rep.blocks.len(), "every dispatch belongs to exactly one kind");
    // The machine-readable view mirrors the table.
    let j = Json::parse(&agg.json().dump()).unwrap();
    assert_eq!(
        j.get("kinds").and_then(|k| k.as_arr()).map(|k| k.len()),
        Some(agg.kinds.len())
    );
    let total_us = j.get("total_us").and_then(|t| t.as_f64()).unwrap();
    assert!((total_us - agg.total_ns as f64 / 1e3).abs() < 1e-6);
}

#[test]
fn calibration_on_tiny_bert_is_sane() {
    let (c, feeds) = tiny_bert();
    let dev = DeviceProfile::s865_cpu();
    let (cal, reps) = calibrate_runs(&c, &feeds, None, 2, 3, &dev).unwrap();
    assert_eq!(reps.len(), 3, "one report per profiled run");
    assert_eq!(cal.runs, 3);
    assert!(!cal.per_kind.is_empty(), "no kernel kinds calibrated");
    assert!(cal.per_kind.iter().any(|k| k.measured_s > 0.0), "all measurements were zero");
    for k in &cal.per_kind {
        assert!(k.blocks > 0);
        assert!(k.predicted_s > 0.0, "model predicted zero cost for {:?}", k.kind);
        assert!(k.rel_err().is_finite());
    }
    assert!(cal.overall_rel_err().is_finite());
    // The fit is a pure per-class rescale: rates stay positive, inside
    // the clamp band, and non-compute constants are untouched.
    let f = &cal.fitted;
    assert_eq!(f.name, "calibrated");
    for (fit, base) in [
        (f.matmul_flops, dev.matmul_flops),
        (f.int8_matmul_flops, dev.int8_matmul_flops),
        (f.vector_flops, dev.vector_flops),
    ] {
        assert!(fit > 0.0);
        assert!(fit >= base * 1e-3 * 0.999 && fit <= base * 1e3 * 1.001);
    }
    assert_eq!(f.mem_bw, dev.mem_bw);
    assert_eq!(f.launch_overhead_s, dev.launch_overhead_s);
    // The JSON view parses back with the same cardinality.
    let j = Json::parse(&cal.json().dump()).unwrap();
    assert!(j.get("overall_rel_err").and_then(|e| e.as_f64()).is_some());
    assert_eq!(
        j.get("per_kind").and_then(|a| a.as_arr()).map(|a| a.len()),
        Some(cal.per_kind.len())
    );
}
