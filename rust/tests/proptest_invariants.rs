//! Property-based tests (via the in-tree `forall` substrate) on the
//! compiler's core invariants, driven by randomly generated graphs.
//!
//! Invariants:
//!  P1  semantics: fused-plan execution == reference interpreter, for any
//!      random elementwise/matmul/reduce DAG and any fusion config;
//!  P2  algebraic rewrites preserve values;
//!  P3  every LP-Fusion partition is a valid partition (each op in exactly
//!      one block, block DAG acyclic, topo-ordered);
//!  P4  both Fig. 4 schedules agree on every broadcast block;
//!  P5  the device cost model is monotone: fused latency <= unfused.

use std::collections::HashMap;

use canao::compiler::exec::interp::eval_graph;
use canao::compiler::exec::plan::execute_plan;
use canao::compiler::fusion::{lp_fusion, FusionConfig};
use canao::compiler::ir::{DType, Graph, Op};
use canao::compiler::passes::PassManager;
use canao::compiler::poly::{schedules_for, Schedule};
use canao::device::{plan_latency, DeviceProfile};
use canao::util::check::{assert_close, forall};
use canao::util::rng::Rng;

/// Generate a random DAG of elementwise / reduce / matmul ops over a few
/// leaf tensors, with broadcast-compatible shapes.
fn random_graph(rng: &mut Rng) -> Graph {
    let mut g = Graph::new();
    let m = 2 + rng.below(6);
    let n = 2 + rng.below(6);
    let full = g.input("x0", &[m, n], DType::F32);
    let row = g.input("x1", &[n], DType::F32);
    let full2 = g.weight("w0", &[m, n]);
    let mut values = vec![full, row, full2];

    let n_ops = 3 + rng.below(10);
    for _ in 0..n_ops {
        let a = *rng.choose(&values);
        let b = *rng.choose(&values);
        let choice = rng.below(8);
        let id = match choice {
            0 => g.add(a, b),
            1 => g.mul(a, b),
            2 => g.sub(a, b),
            3 => g.add_op(Op::Tanh, &[a]),
            4 => g.add_op(Op::Exp, &[a]),
            5 => {
                let c = g.constant(0.5 + rng.f32());
                g.mul(a, c)
            }
            6 => {
                // max-based (softmax-ish) fragment
                let r = g.add_op(Op::ReduceMax { axis: g.nodes[a].shape.rank() - 1 }, &[a]);
                g.sub(a, r)
            }
            _ => g.add_op(Op::Max, &[a, b]),
        };
        values.push(id);
    }
    // 1-2 outputs.
    let o1 = *rng.choose(&values[3..].to_vec().as_slice());
    g.mark_output(o1);
    if rng.below(2) == 0 {
        let o2 = *rng.choose(&values[3..].to_vec().as_slice());
        if o2 != o1 {
            g.mark_output(o2);
        }
    }
    g
}

fn feeds_for(g: &Graph, rng: &mut Rng) -> HashMap<String, Vec<f32>> {
    let mut feeds = HashMap::new();
    for node in &g.nodes {
        if let Op::Input { name } | Op::Weight { name } = &node.op {
            feeds.insert(
                name.clone(),
                (0..node.shape.numel()).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            );
        }
    }
    feeds
}

#[test]
fn p1_plan_execution_matches_interpreter() {
    forall(
        0xA11CE,
        60,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            let budget = if rng.below(2) == 0 { 1 << 26 } else { 256 };
            (g, feeds, budget)
        },
        |(g, feeds, budget)| {
            let expect = eval_graph(g, feeds).map_err(|e| e.to_string())?;
            let cfg = FusionConfig { footprint_budget: *budget, ..Default::default() };
            let plan = lp_fusion(g, &cfg);
            let got =
                execute_plan(g, &plan, feeds, &HashMap::new()).map_err(|e| e.to_string())?;
            for (e, o) in expect.iter().zip(&got) {
                assert_close(&o.data, &e.data, 1e-4, 1e-5)?;
            }
            Ok(())
        },
    );
}

#[test]
fn p2_passes_preserve_semantics() {
    forall(
        0xBEEF,
        60,
        |rng| {
            let g = random_graph(rng);
            let feeds = feeds_for(&g, rng);
            (g, feeds)
        },
        |(g, feeds)| {
            let expect = eval_graph(g, feeds).map_err(|e| e.to_string())?;
            let (optimized, _) = PassManager::standard().run(g);
            let got = eval_graph(&optimized, feeds).map_err(|e| e.to_string())?;
            if optimized.num_ops() > g.num_ops() {
                return Err(format!(
                    "passes grew the graph: {} -> {}",
                    g.num_ops(),
                    optimized.num_ops()
                ));
            }
            for (e, o) in expect.iter().zip(&got) {
                assert_close(&o.data, &e.data, 1e-4, 1e-5)?;
            }
            Ok(())
        },
    );
}

#[test]
fn p3_fusion_is_valid_partition() {
    forall(
        0xCAFE,
        80,
        |rng| random_graph(rng),
        |g| {
            let plan = lp_fusion(g, &FusionConfig::default());
            // Each non-leaf node in exactly one block.
            let mut seen = std::collections::HashSet::new();
            for b in &plan.blocks {
                for &n in &b.nodes {
                    if !seen.insert(n) {
                        return Err(format!("node {n} in two blocks"));
                    }
                }
                // Topo order inside the block.
                for w in b.nodes.windows(2) {
                    if w[0] >= w[1] {
                        return Err(format!("block {} not topo-sorted", b.id));
                    }
                }
            }
            let non_leaf = g.nodes.iter().filter(|n| !n.op.is_leaf()).count();
            if seen.len() != non_leaf {
                return Err(format!("covered {} of {} ops", seen.len(), non_leaf));
            }
            // Block DAG acyclicity: since blocks are emitted in topo order
            // of their first node and the merge rule forbids external
            // users of non-final blocks, it suffices that every block's
            // inputs come from strictly earlier-emitted values.
            for b in &plan.blocks {
                for &i in &b.inputs {
                    if !g.nodes[i].op.is_leaf() {
                        let src_block = plan.block_of[&i];
                        if src_block >= b.id {
                            return Err(format!("block {} reads from block {src_block}", b.id));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p4_fig4_schedules_agree() {
    forall(
        0xD00D,
        40,
        |rng| {
            let m = 1 + rng.below(24);
            let n = 1 + rng.below(24);
            let mut g = Graph::new();
            let a = g.input("a", &[m, n], DType::F32);
            let b = g.input("b", &[m, n], DType::F32);
            let c = g.input("c", &[n], DType::F32);
            let d = g.input("d", &[n], DType::F32);
            let m1 = g.mul(a, b);
            let m2 = g.mul(c, d);
            let s = g.add(m1, m2);
            let t = g.add_op(Op::Tanh, &[s]);
            g.mark_output(t);
            let feeds = feeds_for(&g, rng);
            (g, feeds)
        },
        |(g, feeds)| {
            let plan = lp_fusion(g, &FusionConfig::default());
            if plan.blocks.len() != 1 {
                return Err(format!("expected 1 block, got {}", plan.blocks.len()));
            }
            let scheds = schedules_for(g, &plan.blocks[0]);
            if scheds.len() != 2 {
                return Err(format!("expected both schedules, got {scheds:?}"));
            }
            let mut outs = Vec::new();
            for s in [Schedule::RowRecompute, Schedule::HoistedColMajor] {
                let mut choice = HashMap::new();
                choice.insert(plan.blocks[0].id, s);
                outs.push(execute_plan(g, &plan, feeds, &choice).map_err(|e| e.to_string())?);
            }
            assert_close(&outs[0][0].data, &outs[1][0].data, 1e-5, 1e-6)
        },
    );
}

#[test]
fn p5_fusion_never_slower_in_cost_model() {
    forall(
        0xFEED,
        40,
        |rng| random_graph(rng),
        |g| {
            let fused = lp_fusion(g, &FusionConfig::default());
            let unfused = lp_fusion(g, &FusionConfig::disabled());
            for dev in [DeviceProfile::s865_cpu(), DeviceProfile::s865_gpu()] {
                let lf = plan_latency(g, &fused, &dev);
                let lu = plan_latency(g, &unfused, &dev);
                if lf.total_s > lu.total_s * 1.0001 {
                    return Err(format!(
                        "{}: fused {:.3}ms > unfused {:.3}ms",
                        dev.name,
                        lf.ms(),
                        lu.ms()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn p6_tokenizer_roundtrip_on_corpus_words() {
    use canao::tokenizer::{Tokenizer, Vocab};
    let corpus = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/data/tiny_corpus.txt"),
    )
    .unwrap();
    let tok = Tokenizer::new(Vocab::build(&corpus, 2048));
    let words: Vec<String> = canao::tokenizer::pre_tokenize(&corpus);
    forall(
        0x70C,
        100,
        |rng| {
            let k = 1 + rng.below(12);
            (0..k).map(|_| rng.choose(&words).clone()).collect::<Vec<_>>().join(" ")
        },
        |text| {
            let ids = tok.encode(text);
            let decoded = tok.decode(&ids);
            if decoded != *text {
                return Err(format!("{text:?} -> {decoded:?}"));
            }
            Ok(())
        },
    );
}
