//! End-to-end PJRT tests: load the AOT artifacts, execute, validate
//! numerics and the full QA / text-gen / fine-tune paths.
//!
//! Requires `make artifacts` to have run (skips otherwise, so `cargo test`
//! stays green in a fresh checkout).

use std::sync::Arc;

use canao::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use canao::serving::{GenEngine, GenRequest, QaEngine, QaRequest};
use canao::tokenizer::{Tokenizer, Vocab};
use canao::train;
use canao::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn corpus_tokenizer() -> Arc<Tokenizer> {
    let corpus = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/data/tiny_corpus.txt"),
    )
    .expect("corpus");
    Arc::new(Tokenizer::new(Vocab::build(&corpus, 2048)))
}

/// The Fig. 4 micro artifact: out = a*b + broadcast(c*d). Checked against
/// exact Rust arithmetic — proves HLO-text round-trip numerics.
#[test]
fn fused_add_micro_numerics() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let exe = rt.load("fused_add_micro").unwrap();

    let (m, n) = (64, 96);
    let mut rng = Rng::new(42);
    let a: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let c: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let d: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let out = exe
        .run(
            &[],
            &[
                lit_f32(&a, &[m, n]).unwrap(),
                lit_f32(&b, &[m, n]).unwrap(),
                lit_f32(&c, &[n]).unwrap(),
                lit_f32(&d, &[n]).unwrap(),
            ],
        )
        .unwrap();
    let got = to_vec_f32(&out[0]).unwrap();
    assert_eq!(got.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let expect = a[i * n + j] * b[i * n + j] + c[j] * d[j];
            let g = got[i * n + j];
            assert!((g - expect).abs() < 1e-5, "({i},{j}): {g} vs {expect}");
        }
    }
}

#[test]
fn qa_forward_shapes_and_masking() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let exe = rt.load("qa_b1").unwrap();
    let params = rt.load_params("qa").unwrap();
    let seq = rt.manifest.models["qa"].cfg("seq");

    let ids = vec![5i32; seq];
    let tt = vec![0i32; seq];
    let mut mask = vec![0.0f32; seq];
    for m in mask.iter_mut().take(10) {
        *m = 1.0;
    }
    let out = exe
        .run(
            &params,
            &[
                lit_i32(&ids, &[1, seq]).unwrap(),
                lit_i32(&tt, &[1, seq]).unwrap(),
                lit_f32(&mask, &[1, seq]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
    let start = to_vec_f32(&out[0]).unwrap();
    let end = to_vec_f32(&out[1]).unwrap();
    assert_eq!(start.len(), seq);
    // Padded positions are forced to -1e9 by the QA head.
    assert!(start[0].is_finite() && start[0] > -1e8);
    assert!(start[20] < -1e8 && end[20] < -1e8);
}

#[test]
fn qa_engine_answers_from_context() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let engine = QaEngine::new(&mut rt, corpus_tokenizer()).unwrap();
    let reqs = vec![QaRequest {
        question: "what reduces the kernels ?".into(),
        context: "layer fusion reduces the number of kernels and the memory traffic .".into(),
    }];
    let resp = &engine.answer_batch(&reqs).unwrap()[0];
    // Weights are random-init: the exact span is arbitrary, but it must be
    // a legal span inside the context with decodable text.
    assert!(resp.start_token <= resp.end_token);
    assert!(resp.score.is_finite());
    assert!(!resp.answer.is_empty());
}

#[test]
fn qa_batch8_matches_single() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let engine = QaEngine::new(&mut rt, corpus_tokenizer()).unwrap();
    let req = QaRequest {
        question: "what loads the program ?".into(),
        context: "the runtime loads the compiled program and executes it on the device .".into(),
    };
    let single = &engine.answer_batch(std::slice::from_ref(&req)).unwrap()[0];
    let batch = engine.answer_batch(&vec![req.clone(); 8]).unwrap();
    for b in &batch {
        assert_eq!(b.start_token, single.start_token, "batch vs single span start");
        assert_eq!(b.end_token, single.end_token);
        assert!((b.score - single.score).abs() < 1e-3);
    }
}

#[test]
fn textgen_produces_tokens() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let engine = GenEngine::new(&mut rt, corpus_tokenizer()).unwrap();
    let resp = engine
        .generate(&GenRequest {
            prompt: "the model".into(),
            max_new_tokens: 5,
            temperature: 0.0,
            seed: 1,
        })
        .unwrap();
    assert_eq!(resp.tokens_generated, 5);
    assert_eq!(resp.per_token_ms.len(), 5);
    assert!(!resp.text.is_empty());
}

#[test]
fn textgen_greedy_is_deterministic() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let engine = GenEngine::new(&mut rt, corpus_tokenizer()).unwrap();
    let req =
        GenRequest { prompt: "the device".into(), max_new_tokens: 4, temperature: 0.0, seed: 1 };
    let a = engine.generate(&req).unwrap();
    let b = engine.generate(&GenRequest { seed: 99, ..req.clone() }).unwrap();
    assert_eq!(a.text, b.text, "greedy decode must ignore the seed");
}

#[test]
fn finetune_cls_loss_decreases() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let report = train::finetune_cls(&mut rt, 12, 0.05, 7).unwrap();
    assert_eq!(report.steps, 12);
    // First loss ~ ln(2) for a 2-class random-init head.
    assert!((report.initial_loss - 0.693).abs() < 0.3, "{}", report.initial_loss);
    assert!(report.improved(), "{} -> {}", report.initial_loss, report.final_loss);
}

#[test]
fn train_lm_loss_decreases_on_corpus() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let tok = corpus_tokenizer();
    let corpus = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/data/tiny_corpus.txt"),
    )
    .unwrap();
    let ids: Vec<i32> = tok.encode(&corpus).iter().map(|&t| t as i32).collect();
    let (_params, report) = train::train_lm(&mut rt, &ids, 10, 0.3, 3).unwrap();
    // Initial loss near ln(vocab) for random init.
    let vocab = rt.manifest.models["gen"].cfg("vocab") as f32;
    assert!((report.initial_loss - vocab.ln()).abs() < 1.5, "{}", report.initial_loss);
    assert!(report.improved(), "{} -> {}", report.initial_loss, report.final_loss);
}
