//! Request-scoped tracing integration pins (PR 9):
//!
//! * traced runs are bitwise identical to untraced runs — tracing only
//!   brackets phases with clock reads, never touches model/RNG state;
//! * no tracer attached means zero samples and no request ids;
//! * the `BENCH_trace.json` document schema is pinned, like
//!   `tests/profile.rs` pins the chrome trace_event schema;
//! * a sampled slow request's span tree accounts for >= 95% of its
//!   caller-observed latency (the end-to-end attribution contract).

use std::sync::Arc;
use std::time::{Duration, Instant};

use canao::model::BertConfig;
use canao::serving::{
    GenBatcher, GenBatcherOptions, GenRequest, NativeGenEngine, Phase, TraceConfig, Tracer,
    REQUEST_LANE_BASE,
};
use canao::tokenizer::{Tokenizer, Vocab};
use canao::util::json::Json;

const CORPUS: &str = "the quick brown fox jumps over the lazy dog . \
                      the model generates new sentences word by word . \
                      layer fusion reduces the number of kernels .";

/// Engine weights are drawn from a fixed seed, so two engines built
/// from the same config are identical — the untraced batch-1 reference
/// and the traced scheduler compare across separate instances.
fn tiny_gen(threads: usize) -> NativeGenEngine {
    let tok = Arc::new(Tokenizer::new(Vocab::build(CORPUS, 256)));
    let cfg = BertConfig { vocab: 256, seq: 12, layers: 1, hidden: 8, heads: 2, inter: 16 };
    NativeGenEngine::new(tok, cfg, threads)
}

/// A larger model for the latency-coverage pin: enough compute per wave
/// that fixed scheduling gaps are a small fraction of the total.
fn slow_gen(threads: usize) -> NativeGenEngine {
    let tok = Arc::new(Tokenizer::new(Vocab::build(CORPUS, 256)));
    let cfg = BertConfig { vocab: 256, seq: 64, layers: 2, hidden: 32, heads: 2, inter: 64 };
    NativeGenEngine::new(tok, cfg, threads)
}

fn req(prompt: &str, max_new_tokens: usize, seed: u64) -> GenRequest {
    GenRequest { prompt: prompt.into(), max_new_tokens, temperature: 0.9, seed }
}

#[test]
fn traced_batched_run_is_bitwise_equal_to_untraced_batch1() {
    let reqs: Vec<GenRequest> =
        [("the model", 2usize), ("the quick brown", 4), ("fox", 6), ("lazy dog", 8)]
            .iter()
            .enumerate()
            .map(|(i, &(p, n))| req(p, n, 40 + i as u64))
            .collect();
    let reference: Vec<_> = {
        let eng = tiny_gen(2);
        reqs.iter().map(|r| eng.generate(r).expect("untraced reference")).collect()
    };

    let tracer = Tracer::shared(TraceConfig::default());
    let gb = GenBatcher::new(
        tiny_gen(2),
        GenBatcherOptions {
            max_slots: 4,
            tracer: Some(Arc::clone(&tracer)),
            time_phases: true,
            ..Default::default()
        },
    );
    let rxs: Vec<_> = reqs.iter().map(|r| gb.submit(r.clone()).expect("4 slots free")).collect();
    for (i, (rx, want)) in rxs.into_iter().zip(&reference).enumerate() {
        let got = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("no caller hangs")
            .expect("session succeeds");
        assert_eq!(got.text, want.text, "session {i}: tracing changed the generated text");
        assert_eq!(got.tokens_generated, want.tokens_generated, "session {i}");
        assert_eq!(got.request_id, Some(i as u64), "ids assigned in submit order");
    }
    // Phase timing rode along without perturbing anything either.
    assert!(gb.metrics.decode_phases.steps.get() > 0, "batched phase split recorded");
    let metrics = Arc::clone(&gb.metrics);
    gb.shutdown();
    assert_eq!(metrics.failed.get(), 0);

    let rep = tracer.report();
    assert_eq!(rep.requests, 4);
    assert_eq!(rep.detailed, 4, "sample_every=1 details everything");
    assert_eq!(rep.errors, 0);
    // All four sit in the bootstrap tail window -> full span trees.
    assert_eq!(rep.retained.len(), 4);
    for rt in &rep.retained {
        assert!(!rt.error);
        assert!(rt.spans.iter().any(|s| s.phase == Phase::QueueWait), "queue_wait recorded");
        assert!(rt.phase_ns(Phase::Admit) > 0, "admit (prefill inside) recorded");
        assert!(rt.spans.iter().any(|s| s.phase == Phase::StepWave), "waves recorded");
        let wave = rt.spans.iter().find(|s| s.phase == Phase::StepWave).unwrap();
        assert!(wave.occupancy >= 1, "wave spans carry the dispatched rung");
        assert!(wave.co_resident >= 1 && wave.co_resident <= 4);
    }
}

#[test]
fn no_tracer_means_no_ids_and_identical_output() {
    let want = tiny_gen(1).generate(&req("the model", 3, 7)).unwrap();
    let gb = GenBatcher::new(tiny_gen(1), GenBatcherOptions { max_slots: 2, ..Default::default() });
    let got = gb.call(req("the model", 3, 7)).expect("session succeeds");
    assert_eq!(got.text, want.text, "untraced scheduler matches batch-1");
    assert_eq!(got.request_id, None, "no tracer -> no request ids, zero samples");
    gb.shutdown();
}

#[test]
fn head_sampling_gates_detail_on_the_real_scheduler() {
    let tracer = Tracer::shared(TraceConfig { sample_every: 2, ..TraceConfig::default() });
    let gb = GenBatcher::new(
        tiny_gen(1),
        GenBatcherOptions { max_slots: 1, tracer: Some(Arc::clone(&tracer)), ..Default::default() },
    );
    for i in 0..4u64 {
        // One at a time: the 1-slot scheduler serializes, so ids are
        // assigned 0..4 in order and alternate detailed/summary-only.
        let resp = gb.call(req("the model", 2, i)).expect("session succeeds");
        assert_eq!(resp.request_id, Some(i));
    }
    gb.shutdown();
    let rep = tracer.report();
    assert_eq!(rep.requests, 4, "sampled-out requests still count");
    assert_eq!(rep.detailed, 2, "every 2nd request records spans");
    assert_eq!(rep.errors, 0);
    assert_eq!(rep.retained.len(), 2, "only detailed requests retain span trees");
}

#[test]
fn trace_json_schema_is_pinned() {
    let tracer = Tracer::shared(TraceConfig::default());
    let gb = GenBatcher::new(
        tiny_gen(1),
        GenBatcherOptions { max_slots: 2, tracer: Some(Arc::clone(&tracer)), ..Default::default() },
    );
    for i in 0..2u64 {
        gb.call(req("the model generates", 3, i)).expect("session succeeds");
    }
    gb.shutdown();
    let rep = tracer.report();
    let parsed = Json::parse(&rep.json().dump_pretty()).expect("BENCH_trace.json parses");

    assert_eq!(parsed.get("schema").unwrap().as_usize(), Some(1));
    assert_eq!(parsed.get("bench").unwrap().as_str(), Some("trace"));
    assert_eq!(parsed.get("requests").unwrap().as_usize(), Some(2));
    for key in ["detailed", "errors", "tail_pct", "total_p50_us", "total_p95_us", "total_p99_us"] {
        assert!(parsed.get(key).unwrap().as_f64().is_some(), "top-level `{key}`");
    }
    let phases = parsed.get("phases").expect("phases object");
    for label in ["queue_wait", "admit", "prefill", "step_wave", "sample", "retire", "run"] {
        let p = phases.get(label).unwrap_or_else(|| panic!("phase `{label}` missing"));
        for k in ["count", "p50_us", "p95_us", "p99_us", "max_us", "mean_us"] {
            assert!(p.get(k).unwrap().as_f64().is_some(), "{label}.{k}");
        }
    }
    let retained = parsed.get("retained").and_then(|r| r.as_arr()).expect("retained array");
    assert_eq!(retained.len(), 2);
    for rt in retained {
        for k in ["id", "error", "start_us", "total_us"] {
            assert!(rt.get(k).is_some(), "retained.{k}");
        }
        let spans = rt.get("spans").and_then(|s| s.as_arr()).expect("spans array");
        assert!(!spans.is_empty());
        for s in spans {
            for k in ["phase", "start_us", "dur_us", "occupancy", "co_resident"] {
                assert!(s.get(k).is_some(), "span.{k}");
            }
        }
        assert!(rt.get("events").and_then(|e| e.as_arr()).is_some(), "events array");
    }

    // The chrome view puts every retained request on its own lane at
    // REQUEST_LANE_BASE+, wrapped in the profiler's envelope.
    let chrome = Json::parse(&rep.chrome_trace().dump()).unwrap();
    assert_eq!(chrome.get("displayTimeUnit").and_then(|d| d.as_str()), Some("ns"));
    let events = chrome.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    assert!(!events.is_empty());
    for ev in events {
        let tid = ev.get("tid").and_then(|t| t.as_f64()).expect("lane tid");
        assert!(tid >= REQUEST_LANE_BASE as f64, "request lanes start at {REQUEST_LANE_BASE}");
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap();
        assert!(ph == "X" || ph == "i", "span or instant events only");
    }
}

#[test]
fn slow_request_span_tree_covers_caller_latency() {
    // The attribution contract: for a tail-sampled request, the recorded
    // span tree explains where the caller's wall time actually went —
    // >= 95% of the caller-observed latency lands inside spans.
    let tracer = Tracer::shared(TraceConfig::default());
    let gb = GenBatcher::new(
        slow_gen(2),
        GenBatcherOptions {
            max_slots: 2,
            tracer: Some(Arc::clone(&tracer)),
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let rx = gb.submit(req("the model generates new sentences", 32, 5)).expect("slot free");
    let resp = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("no caller hangs")
        .expect("session succeeds");
    let caller_ns = t0.elapsed().as_nanos() as u64;
    assert!(resp.tokens_generated >= 16, "a genuinely slow request");
    // Join the worker so the retirement reached the tracer.
    gb.shutdown();

    let rep = tracer.report();
    let rt = rep
        .retained
        .iter()
        .find(|r| Some(r.id) == resp.request_id)
        .expect("slow request retained (bootstrap tail window)");
    // Disjoint top-level phases: queue_wait, admit (prefill + the first
    // sample nest inside it), the step waves, the post-wave samples, and
    // retire. The first sample span is the admit-time one — skip it to
    // avoid double counting.
    let post_wave_samples: u64 = rt
        .spans
        .iter()
        .filter(|s| s.phase == Phase::Sample)
        .skip(1)
        .map(|s| s.dur_ns)
        .sum();
    let covered = rt.phase_ns(Phase::QueueWait)
        + rt.phase_ns(Phase::Admit)
        + rt.phase_ns(Phase::StepWave)
        + rt.phase_ns(Phase::Retire)
        + post_wave_samples;
    assert!(
        covered as f64 >= 0.95 * caller_ns as f64,
        "span tree covers {covered} ns of {caller_ns} ns caller latency \
         ({:.1}%; trace total {} ns)",
        100.0 * covered as f64 / caller_ns as f64,
        rt.total_ns
    );
    assert!(covered <= caller_ns + caller_ns / 4, "spans cannot dwarf the caller's clock");
}
