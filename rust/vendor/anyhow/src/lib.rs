//! Vendored subset of the `anyhow` API (substrate — crates.io is
//! unavailable offline). Covers what this workspace uses: `Result`,
//! `Error`, `anyhow!`, `bail!`, `ensure!`, and the `Context` extension
//! trait on `Result` and `Option`. Error values carry a flattened message
//! (the source chain is rendered into the string at conversion time).

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate, so `anyhow::Result<T>` and `Result<T, E>` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` lowers to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same trick as the real
// anyhow crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain into one message.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let r: std::result::Result<u32, std::io::Error> = Err(std::io::Error::other("boom"));
        let e = r.with_context(|| "reading thing").unwrap_err();
        assert!(e.to_string().starts_with("reading thing: "), "{e}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
