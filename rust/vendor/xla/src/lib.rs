//! API-compatible stub of the `xla` crate's PJRT surface (substrate — the
//! real crate needs a prebuilt XLA C library that is unavailable offline).
//!
//! `Literal` is a real host-side container (so the `lit_*` helpers and any
//! host-only code paths work), but `PjRtClient::cpu()` reports the backend
//! as unavailable: everything downstream of client construction is
//! type-checked, never executed. Serving and tests that need real compute
//! run on the in-tree wave-parallel plan executor instead.

use std::borrow::Borrow;
use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: built against the vendored xla stub \
         (no XLA C library in this environment)"
            .to_string(),
    )
}

// ---- host literals ------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a `Literal` can hold.
pub trait NativeType: Copy + Sized {
    fn to_data(v: Vec<Self>) -> Data;
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_data(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn to_data(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host tensor literal: element data + dims (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::to_data(v.to_vec()), dims: vec![v.len() as i64] }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::to_data(vec![v]), dims: vec![] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data).ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { data: Data::Tuple(elems), dims: vec![] }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

// ---- PJRT stubs ---------------------------------------------------------

#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_literal() {
        let t = Literal::tuple(vec![Literal::scalar(1i32), Literal::scalar(2.0f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
