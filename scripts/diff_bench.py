#!/usr/bin/env python3
"""Shape-diff a fresh bench report against its committed seed.

Usage: diff_bench.py SEED.json FRESH.json

Bench values (latencies, throughput, counts) vary by host, so CI cannot
compare them — what it can pin is the document *shape*: the `schema`
version, the bench name, and the key sets at every object level. A PR
that adds, renames, or drops a field without bumping the schema (or
without regenerating the committed seed) fails here; a PR that merely
runs faster or slower passes.

Rules, applied recursively from the root:

* `null` on either side matches anything — optional sections
  (`ttft`, `page_pool`, `trace`, `git_commit`, ...) are host- and
  flag-dependent;
* two objects must have identical key sets, and each shared key is
  compared recursively;
* two arrays match as arrays (element counts and contents vary by run);
* two scalars must agree on kind (number/string/bool).

Exit status 0 on match; 1 with a per-path report on mismatch.
"""

import json
import sys


def kind(v):
    if isinstance(v, dict):
        return "object"
    if isinstance(v, list):
        return "array"
    if isinstance(v, bool):
        return "bool"
    if isinstance(v, (int, float)):
        return "number"
    if isinstance(v, str):
        return "string"
    return "null"


def diff(seed, fresh, path, errors):
    if seed is None or fresh is None:
        return
    ks, kf = kind(seed), kind(fresh)
    if ks != kf:
        errors.append(f"{path}: seed is {ks}, fresh is {kf}")
        return
    if ks == "object":
        missing = sorted(seed.keys() - fresh.keys())
        extra = sorted(fresh.keys() - seed.keys())
        if missing:
            errors.append(f"{path}: fresh run dropped keys {missing}")
        if extra:
            errors.append(f"{path}: fresh run added keys {extra} (regenerate the seed?)")
        for k in sorted(seed.keys() & fresh.keys()):
            diff(seed[k], fresh[k], f"{path}.{k}", errors)


def main(argv):
    if len(argv) != 3:
        sys.stderr.write(__doc__)
        return 2
    with open(argv[1]) as f:
        seed = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)

    errors = []
    for key in ("schema", "bench"):
        if seed.get(key) != fresh.get(key):
            errors.append(f"$.{key}: seed {seed.get(key)!r} != fresh {fresh.get(key)!r}")
    if not errors:
        diff(seed, fresh, "$", errors)

    if errors:
        print(f"shape diff FAILED: {argv[1]} vs {argv[2]}")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"shape diff OK: {argv[2]} matches {argv[1]} (schema {seed.get('schema')})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
